//! Continuous-batching serving engine over the paged KV cache.
//!
//! The engine interleaves *prefill* and *decode* steps on the trace
//! clock, the way a production serving loop does:
//!
//! - Arrivals queue FIFO. Admission allocates paged KV for the prompt
//!   (forking the shared system prefix when one is configured), gated
//!   by [`crate::serve::kvcache::KvCacheManager::can_admit`].
//! - Newly admitted requests run one **prefill** step (an `Op::AttnFwd`
//!   dispatch at the batch's longest prompt); its completion is the
//!   request's first token, so time-to-first-token (TTFT) is measured
//!   here.
//! - Otherwise the running batch takes one **decode** step (an
//!   `Op::AttnDecode` dispatch at the batch's longest context); each
//!   step emits one token per running sequence and its duration is the
//!   inter-token latency (ITL).
//! - A sequence that cannot grow its KV (pool exhausted, nothing
//!   evictable) is *preempted*: its blocks are freed and it requeues
//!   for a fresh prefill — progress is never silently lost, it is
//!   recomputed.
//!
//! Every step duration comes from `registry` dispatch against an
//! engine-private [`TuneCache`] and the kernel cost model, so a trace
//! replays bit-identically: `BENCH_serve.json` is deterministic across
//! runs (asserted in `tests/serve_engine.rs`).

use crate::coordinator::metrics::LatencyStats;
use crate::error::Result;
use crate::hk::tunecache::TuneCache;
use crate::kernels::registry::{ArchId, Query};
use crate::moe::router::{route, router_softmax_counters, MoeConfig};
use crate::obs::{KernelCounters, Trace};
use crate::runtime::json::Json;
use crate::runtime::Rng;
use crate::bail;
use crate::serve::kvcache::{
    kv_block_bytes, KvCacheConfig, KvCacheManager, KvCacheStats,
};
use crate::serve::sched::{chunk_len, LaneQueues, SchedConfig};
use crate::serve::trace::TracedRequest;
use crate::sim::arch::Dtype;
use std::collections::{HashMap, VecDeque};

/// A memoized step price: simulated wall time plus the hardware-style
/// counter record of the dispatched kernel(s). The engine's rollups
/// (per-lane, per-run) are exact sums of these.
#[derive(Debug, Clone, Copy, Default)]
pub struct StepCost {
    pub time_s: f64,
    pub counters: KernelCounters,
}

/// Reserved prefix id for the engine's shared system prompt.
const SYSTEM_PREFIX: u64 = u64::MAX;

/// Step-cost memo bucket width (tokens): nearby contexts share one
/// dispatch so the memo stays small and the tune cache is exercised.
const CTX_BUCKET: u32 = 256;

/// Engine configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub arch: ArchId,
    /// Paged-KV block size (tokens).
    pub block_size: u32,
    /// Physical blocks in **each GPU's** KV pool.
    pub num_blocks: u32,
    /// Max sequences decoded per step **per GPU** (the continuous batch
    /// width of one GPU's lane).
    pub max_batch: usize,
    /// Simulated GPUs. Each owns a KV pool and a decode lane; requests
    /// are placed on the least-loaded GPU at admission and their KV
    /// never migrates. 1 = the pre-sharding single-GPU engine.
    pub n_gpus: u32,
    pub heads_q: u32,
    pub heads_kv: u32,
    pub d_head: u32,
    /// KV-cache storage dtype. Sets the HBM footprint of one KV block
    /// ([`kv_block_bytes`]) and therefore how many blocks a byte budget
    /// buys ([`Self::with_kv_budget`]) — FP8 KV halves the bytes per
    /// block, so the same HBM holds ~2x the effective KV capacity.
    /// Attention math stays at working precision (the cache is
    /// dequantized on the fly); only the memory plane narrows.
    pub kv_dtype: Dtype,
    /// Shared system-prompt tokens prepended to every request (0 =
    /// disabled). Served from one ref-counted prefix, not re-allocated.
    pub shared_prefix_tokens: u32,
    /// MoE model configuration: when set, every prefill/decode step
    /// additionally issues a router pass + an `Op::MoeGemm` grouped FFN
    /// over the step's token batch. The KV-cache plane (admission
    /// headroom, eviction, preemption) is untouched — MoE only adds
    /// FFN time to the step clock.
    pub moe: Option<MoeServeConfig>,
    /// Memory-bound layer plane: when not [`MbFusion::Off`], every
    /// prefill/decode step additionally pays the Add+RMSNorm and
    /// SiLU+Mul fusion chains over the step's token batch — fused
    /// (one global-memory pass each) or force-split (the per-stage
    /// baseline), so the serving-level win of fusion is measurable.
    pub mb_fusion: MbFusion,
    /// Row width of the membound chains (the model dimension).
    pub mb_d_model: u32,
    /// Production-trace scheduler ([`crate::serve::sched`]): `None`
    /// keeps the legacy lock-step loop bit-for-bit (the default);
    /// `Some` turns on chunked prefill, prefix-aware placement,
    /// cross-lane stealing, SLO admission order, and (optionally)
    /// disaggregated prefill/decode via [`ServeEngine::run_traced`].
    pub sched: Option<SchedConfig>,
}

/// How the engine runs the per-step memory-bound chains.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum MbFusion {
    /// No membound plane on the step clock (the pre-fusion default).
    Off,
    /// Chains fused up to the register/LDS budget.
    Fused,
    /// Chains force-split into one pass per stage (the baseline).
    Split,
}

/// Accounting of the membound-chain plane over a serving run.
#[derive(Debug, Clone, Default)]
pub struct MbServeStats {
    /// Steps that paid the chain plane.
    pub steps: u64,
    /// Total chain time added to the step clock.
    pub time_s: f64,
}

/// MoE layer shape served per step.
#[derive(Debug, Clone, Copy)]
pub struct MoeServeConfig {
    pub experts: u32,
    pub top_k: u32,
    pub d_model: u32,
    /// Hidden width of one expert.
    pub d_ff: u32,
    /// Routing-skew percentage fed to the grouped cost model (0 =
    /// balanced routing).
    pub skew_pct: u32,
}

impl Default for MoeServeConfig {
    fn default() -> Self {
        MoeServeConfig {
            experts: 8,
            top_k: 2,
            d_model: 2048,
            d_ff: 1024,
            skew_pct: 0,
        }
    }
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            arch: ArchId::Mi355x,
            block_size: 16,
            num_blocks: 4096,
            max_batch: 32,
            n_gpus: 1,
            heads_q: 64,
            heads_kv: 8,
            d_head: 128,
            kv_dtype: Dtype::Bf16,
            shared_prefix_tokens: 128,
            moe: None,
            mb_fusion: MbFusion::Off,
            mb_d_model: 2048,
            sched: None,
        }
    }
}

impl ServeConfig {
    /// HBM bytes of one KV block at this config's geometry and dtype.
    pub fn kv_block_bytes(&self) -> f64 {
        kv_block_bytes(self.kv_dtype, self.block_size, self.heads_kv, self.d_head)
    }

    /// Derive `num_blocks` from a **per-GPU** HBM byte budget at the
    /// configured KV dtype and geometry: a narrower `kv_dtype` buys
    /// proportionally more blocks from the same budget (builder style).
    pub fn with_kv_budget(mut self, hbm_budget_bytes: f64) -> Self {
        self.num_blocks = KvCacheConfig::for_hbm_budget(
            hbm_budget_bytes,
            self.kv_dtype,
            self.block_size,
            self.heads_kv,
            self.d_head,
            self.n_gpus,
        )
        .num_blocks;
        self
    }
}

/// One serving request on the trace clock.
#[derive(Debug, Clone, Copy)]
pub struct ServeRequest {
    pub id: u64,
    pub arrival_s: f64,
    pub prompt_tokens: u32,
    pub output_tokens: u32,
}

/// Poisson arrivals with uniformly mixed prompt/output lengths
/// (prompts 64..=512, outputs 16..=128 tokens).
pub fn serve_trace(n: u64, rate: f64, seed: u64) -> Vec<ServeRequest> {
    let mut rng = Rng::new(seed);
    let mut t = 0.0;
    (0..n)
        .map(|id| {
            t += rng.exp(rate);
            ServeRequest {
                id,
                arrival_s: t,
                prompt_tokens: 64 + rng.below(449) as u32,
                output_tokens: 16 + rng.below(113) as u32,
            }
        })
        .collect()
}

/// Outcome of serving a trace.
#[derive(Debug, Clone)]
pub struct ServeReport {
    pub served: u64,
    pub preemptions: u64,
    pub prefill_steps: u64,
    pub decode_steps: u64,
    pub makespan_s: f64,
    /// Delivered output tokens per second of trace time (recomputed
    /// work from preemptions is excluded).
    pub throughput_tok_s: f64,
    /// Time-to-first-token per request.
    pub ttft: LatencyStats,
    /// Inter-token latency per generated token.
    pub itl: LatencyStats,
    /// End-to-end latency per request.
    pub e2e: LatencyStats,
    /// Peak aggregate KV occupancy over the run (all pools), 0..=1.
    pub peak_occupancy: f64,
    /// Run-level counter rollup: the in-order sum of the per-lane
    /// counters (`per_gpu[i].counters`), so the lane-sum invariant is
    /// checkable from the report alone.
    pub counters: KernelCounters,
    pub kv: KvCacheStats,
    /// MoE-side accounting (present when the engine serves an MoE model).
    pub moe: Option<MoeServeStats>,
    /// Membound-chain accounting (present when the plane is enabled).
    pub membound: Option<MbServeStats>,
    /// GPUs the engine served across (one KV pool + decode lane each).
    pub n_gpus: u32,
    /// Per-GPU lane statistics.
    pub per_gpu: Vec<GpuLaneStats>,
    /// Per-tenant latency breakdown (empty on the legacy path, so the
    /// legacy JSON payload is unchanged byte-for-byte).
    pub per_tenant: Vec<TenantLatencyStats>,
    /// Scheduler-side accounting (None on the legacy path).
    pub sched: Option<SchedServeStats>,
}

/// One tenant's share of a scheduled serving run: its SLO class and
/// the latency percentiles the SLO is judged against.
#[derive(Debug, Clone, Default)]
pub struct TenantLatencyStats {
    pub tenant: u32,
    /// SLO class tag ([`crate::serve::trace::SloClass::tag`]).
    pub slo: &'static str,
    /// Requests of this tenant in the trace.
    pub requests: u64,
    /// Requests finished.
    pub served: u64,
    pub ttft: LatencyStats,
    pub itl: LatencyStats,
}

/// Accounting of the scheduled serving loop (chunked prefill, prefix
/// cache, stealing, disaggregated handoff).
#[derive(Debug, Clone, Default)]
pub struct SchedServeStats {
    /// Prefill chunks priced over the run.
    pub chunks: u64,
    /// Prompt tokens processed through those chunks — equals the sum
    /// of every admission's prefill target (chunking never loses or
    /// double-counts a token; asserted in `tests/serve_sched.rs`).
    pub chunk_tokens: u64,
    /// Requests re-routed by idle-lane stealing.
    pub stolen: u64,
    /// Admissions that found their tenant prefix resident (CoW fork,
    /// no prefix recompute).
    pub prefix_hits: u64,
    /// Admissions that had to pin + recompute their tenant prefix.
    pub prefix_misses: u64,
    /// Disaggregated KV handoffs (prefill pool -> decode pool).
    pub handoffs: u64,
    /// Bytes those handoffs moved across the link.
    pub handoff_bytes: f64,
    /// Link seconds the handoffs cost
    /// ([`crate::hk::topology::LinkModel::point_to_point_s`]).
    pub handoff_s: f64,
}

/// One GPU lane's share of a serving run.
#[derive(Debug, Clone, Default)]
pub struct GpuLaneStats {
    /// Prompt admissions placed on this GPU.
    pub admitted: u64,
    /// Decode tokens emitted from this GPU's lane.
    pub decode_tokens: u64,
    /// Peak occupancy of this GPU's KV pool, 0..=1.
    pub peak_occupancy: f64,
    /// Counter rollup of every step this lane paid (attention + MoE
    /// FFN + membound chains).
    pub counters: KernelCounters,
}

/// Aggregated router/grouped-GEMM statistics of an MoE serving run.
#[derive(Debug, Clone, Default)]
pub struct MoeServeStats {
    /// Steps that issued a router + grouped-FFN pass.
    pub steps: u64,
    /// Total FFN time added to the step clock.
    pub ffn_time_s: f64,
    /// Mean Switch-style auxiliary imbalance over the run's router
    /// passes (~1.0 = balanced).
    pub mean_imbalance: f64,
    /// Assignments rerouted by capacity overflow.
    pub rerouted: u64,
    /// Assignment slots dropped — zero whenever the router's 1.25
    /// capacity factor clears the `experts/(experts-top_k+1)` no-drop
    /// bound, which holds for every default shape.
    pub dropped_slots: u64,
}

impl ServeReport {
    pub fn summary(&self) -> String {
        format!(
            "served={} gpus={} preempt={} steps[prefill={} decode={}] makespan={:.3}s \
             {:.0} tok/s ttft[p50={:.0}us p99={:.0}us] itl[p50={:.0}us p99={:.0}us] \
             kv[peak={:.0}% cow={} evicted={} shared_saved={}]",
            self.served,
            self.n_gpus,
            self.preemptions,
            self.prefill_steps,
            self.decode_steps,
            self.makespan_s,
            self.throughput_tok_s,
            self.ttft.p50_us(),
            self.ttft.p99_us(),
            self.itl.p50_us(),
            self.itl.p99_us(),
            self.peak_occupancy * 100.0,
            self.kv.cow_copies,
            self.kv.evicted_blocks,
            self.kv.shared_blocks_saved,
        )
    }

    /// The `BENCH_serve.json` payload. Keys are BTreeMap-ordered and
    /// every number is a deterministic cost-model product, so the dump
    /// is byte-stable across runs.
    pub fn to_json(&self) -> Json {
        let hist = |s: &LatencyStats| {
            Json::Arr(
                s.histogram_us()
                    .into_iter()
                    .map(|(edge, n)| {
                        Json::Arr(vec![Json::Num(edge), Json::Num(n as f64)])
                    })
                    .collect(),
            )
        };
        let mut doc = Json::obj(vec![
            ("counters", self.counters.to_json()),
            ("ttft_hist_us", hist(&self.ttft)),
            ("itl_hist_us", hist(&self.itl)),
            ("e2e_hist_us", hist(&self.e2e)),
            ("served", Json::Num(self.served as f64)),
            ("preemptions", Json::Num(self.preemptions as f64)),
            ("prefill_steps", Json::Num(self.prefill_steps as f64)),
            ("decode_steps", Json::Num(self.decode_steps as f64)),
            ("makespan_s", Json::Num(self.makespan_s)),
            ("throughput_tok_s", Json::Num(self.throughput_tok_s)),
            ("ttft_p50_us", Json::Num(self.ttft.p50_us())),
            ("ttft_p99_us", Json::Num(self.ttft.p99_us())),
            ("itl_p50_us", Json::Num(self.itl.p50_us())),
            ("itl_p99_us", Json::Num(self.itl.p99_us())),
            ("e2e_p50_us", Json::Num(self.e2e.p50_us())),
            ("e2e_p99_us", Json::Num(self.e2e.p99_us())),
            ("peak_occupancy", Json::Num(self.peak_occupancy)),
            ("kv_allocated", Json::Num(self.kv.allocated_blocks as f64)),
            ("kv_freed", Json::Num(self.kv.freed_blocks as f64)),
            ("kv_cow_copies", Json::Num(self.kv.cow_copies as f64)),
            (
                "kv_shared_saved",
                Json::Num(self.kv.shared_blocks_saved as f64),
            ),
            ("kv_evicted", Json::Num(self.kv.evicted_blocks as f64)),
            ("n_gpus", Json::Num(self.n_gpus as f64)),
            (
                "per_gpu",
                Json::Arr(
                    self.per_gpu
                        .iter()
                        .map(|g| {
                            Json::obj(vec![
                                ("admitted", Json::Num(g.admitted as f64)),
                                (
                                    "decode_tokens",
                                    Json::Num(g.decode_tokens as f64),
                                ),
                                (
                                    "peak_occupancy",
                                    Json::Num(g.peak_occupancy),
                                ),
                                ("counters", g.counters.to_json()),
                            ])
                        })
                        .collect(),
                ),
            ),
        ]);
        if let Some(m) = &self.moe {
            let Json::Obj(map) = &mut doc else { unreachable!() };
            map.insert(
                "moe".to_string(),
                Json::obj(vec![
                    ("steps", Json::Num(m.steps as f64)),
                    ("ffn_time_s", Json::Num(m.ffn_time_s)),
                    ("mean_imbalance", Json::Num(m.mean_imbalance)),
                    ("rerouted", Json::Num(m.rerouted as f64)),
                    ("dropped_slots", Json::Num(m.dropped_slots as f64)),
                ]),
            );
        }
        if let Some(m) = &self.membound {
            let Json::Obj(map) = &mut doc else { unreachable!() };
            map.insert(
                "membound".to_string(),
                Json::obj(vec![
                    ("steps", Json::Num(m.steps as f64)),
                    ("time_s", Json::Num(m.time_s)),
                ]),
            );
        }
        if !self.per_tenant.is_empty() {
            let Json::Obj(map) = &mut doc else { unreachable!() };
            map.insert(
                "per_tenant".to_string(),
                Json::Arr(
                    self.per_tenant
                        .iter()
                        .map(|t| {
                            Json::obj(vec![
                                ("tenant", Json::Num(t.tenant as f64)),
                                ("slo", Json::Str(t.slo.to_string())),
                                ("requests", Json::Num(t.requests as f64)),
                                ("served", Json::Num(t.served as f64)),
                                ("ttft_p50_us", Json::Num(t.ttft.p50_us())),
                                ("ttft_p99_us", Json::Num(t.ttft.p99_us())),
                                ("itl_p50_us", Json::Num(t.itl.p50_us())),
                                ("itl_p99_us", Json::Num(t.itl.p99_us())),
                            ])
                        })
                        .collect(),
                ),
            );
        }
        if let Some(s) = &self.sched {
            let Json::Obj(map) = &mut doc else { unreachable!() };
            map.insert(
                "sched".to_string(),
                Json::obj(vec![
                    ("chunks", Json::Num(s.chunks as f64)),
                    ("chunk_tokens", Json::Num(s.chunk_tokens as f64)),
                    ("stolen", Json::Num(s.stolen as f64)),
                    ("prefix_hits", Json::Num(s.prefix_hits as f64)),
                    ("prefix_misses", Json::Num(s.prefix_misses as f64)),
                    ("handoffs", Json::Num(s.handoffs as f64)),
                    ("handoff_bytes", Json::Num(s.handoff_bytes)),
                    ("handoff_s", Json::Num(s.handoff_s)),
                ]),
            );
        }
        doc
    }
}

struct Running {
    idx: usize,
    decoded: u32,
    /// The GPU lane whose KV pool holds this sequence.
    gpu: u32,
}

/// A request mid-chunked-prefill on one lane.
struct Prefilling {
    idx: usize,
    gpu: u32,
    /// Prompt tokens already computed through chunks.
    done: u32,
    /// Prompt tokens this admission must compute (excludes a resident
    /// tenant prefix — a CoW hit skips the prefix recompute entirely).
    target: u32,
    /// KV context already resident when the first chunk runs (the
    /// forked prefix on a hit, 0 on a cold admission) — chunk costs
    /// attend over it without recomputing it.
    base: u32,
}

/// Per-field difference of two cumulative counter records, used to
/// price one prefill chunk as `cum(end) - cum(start)`. Floats clamp at
/// zero and tallies saturate (the cost model's cumulative curves are
/// monotone, but bucketless dispatch gives no hard guarantee);
/// `reg_demand` is a peak, not a tally, so the chunk keeps the larger
/// record's demand; `kernels` is pinned to 1 — one chunk is one launch.
fn counters_delta(hi: &KernelCounters, lo: &KernelCounters) -> KernelCounters {
    let d = |a: f64, b: f64| (a - b).max(0.0);
    KernelCounters {
        hbm_read_bytes: d(hi.hbm_read_bytes, lo.hbm_read_bytes),
        hbm_write_bytes: d(hi.hbm_write_bytes, lo.hbm_write_bytes),
        l2_bytes: d(hi.l2_bytes, lo.l2_bytes),
        lds_bytes: d(hi.lds_bytes, lo.lds_bytes),
        mfma_flops: d(hi.mfma_flops, lo.mfma_flops),
        issued_waves: d(hi.issued_waves, lo.issued_waves),
        reg_demand: hi.reg_demand.max(lo.reg_demand),
        spill_cycles: d(hi.spill_cycles, lo.spill_cycles),
        atomic_rmw_bytes: d(hi.atomic_rmw_bytes, lo.atomic_rmw_bytes),
        cross_gpu_bytes: d(hi.cross_gpu_bytes, lo.cross_gpu_bytes),
        scale_bytes: d(hi.scale_bytes, lo.scale_bytes),
        fused_passes: hi.fused_passes.saturating_sub(lo.fused_passes),
        forced_splits: hi.forced_splits.saturating_sub(lo.forced_splits),
        kernels: 1,
    }
}

/// Emit KV-plane instants for whatever changed between two stats
/// snapshots (CoW copies, evictions) at trace time `now`.
fn kv_delta_instants(
    t: &mut Trace,
    pid: u32,
    now: f64,
    prev: &KvCacheStats,
    cur: &KvCacheStats,
) {
    let cow = cur.cow_copies - prev.cow_copies;
    if cow > 0 {
        t.instant(pid, 0, "kv", "kv-cow", now, vec![(
            "count".to_string(),
            Json::Num(cow as f64),
        )]);
    }
    let evicted = cur.evicted_blocks - prev.evicted_blocks;
    if evicted > 0 {
        t.instant(pid, 0, "kv", "kv-evict", now, vec![(
            "blocks".to_string(),
            Json::Num(evicted as f64),
        )]);
    }
}

/// The continuous-batching engine.
pub struct ServeEngine {
    cfg: ServeConfig,
    kv: KvCacheManager,
    cache: TuneCache,
    prefill_memo: HashMap<(u32, u32), StepCost>,
    decode_memo: HashMap<(u32, u32), StepCost>,
    /// MoE FFN step cost memo, keyed by routed token count.
    moe_memo: HashMap<u32, StepCost>,
    /// Membound-chain step cost memo, keyed by step token count: one
    /// (chain name, cost) entry per chain so the timeline can render
    /// the sub-spans individually.
    mb_memo: HashMap<u32, Vec<(&'static str, StepCost)>>,
    /// Cumulative whole-prefill cost memo at *exact* (unbucketed)
    /// context length — the curve chunked prefill differences, so
    /// chunk costs telescope exactly to the whole-prompt prefill.
    chunk_memo: HashMap<u32, StepCost>,
    /// Timeline under construction when tracing is enabled
    /// ([`Self::enable_trace`]); taken by [`Self::take_trace`].
    timeline: Option<Trace>,
}

impl ServeEngine {
    pub fn new(cfg: ServeConfig) -> Result<Self> {
        if cfg.block_size == 0 || cfg.num_blocks == 0 || cfg.max_batch == 0 {
            bail!("serve config needs nonzero block_size/num_blocks/max_batch");
        }
        if cfg.n_gpus == 0 {
            bail!("serve config needs at least one GPU");
        }
        let kv = KvCacheManager::new(KvCacheConfig {
            num_blocks: cfg.num_blocks,
            block_size: cfg.block_size,
            n_gpus: cfg.n_gpus,
        });
        Ok(ServeEngine {
            cfg,
            kv,
            cache: TuneCache::new(),
            prefill_memo: HashMap::new(),
            decode_memo: HashMap::new(),
            moe_memo: HashMap::new(),
            mb_memo: HashMap::new(),
            chunk_memo: HashMap::new(),
            timeline: None,
        })
    }

    pub fn kv(&self) -> &KvCacheManager {
        &self.kv
    }

    /// Record a Chrome-trace timeline during the next [`Self::run_trace`]
    /// (lane spans, KV/preemption/router instants on the sim clock).
    pub fn enable_trace(&mut self) {
        self.timeline = Some(Trace::new());
    }

    /// Take the recorded timeline (None when tracing was never enabled).
    pub fn take_trace(&mut self) -> Option<Trace> {
        self.timeline.take()
    }

    fn bucket(n: u32) -> u32 {
        n.div_ceil(CTX_BUCKET).max(1) * CTX_BUCKET
    }

    /// Simulated cost of one prefill step (batch x longest prompt).
    fn prefill_step(&mut self, batch: u32, seq: u32) -> StepCost {
        let key = (batch, Self::bucket(seq));
        if let Some(&c) = self.prefill_memo.get(&key) {
            return c;
        }
        let q = Query::attn(
            self.cfg.arch,
            batch,
            self.cfg.heads_q,
            self.cfg.heads_kv,
            key.1,
            self.cfg.d_head,
            true,
        );
        let perf = q.dispatch_with(&mut self.cache).simulate();
        let c = StepCost { time_s: perf.time_s, counters: perf.counters };
        self.prefill_memo.insert(key, c);
        c
    }

    /// Simulated cost of one decode step (batch x longest context).
    fn decode_step(&mut self, batch: u32, context: u32) -> StepCost {
        let key = (batch, Self::bucket(context));
        if let Some(&c) = self.decode_memo.get(&key) {
            return c;
        }
        let q = Query::attn_decode(
            self.cfg.arch,
            batch,
            self.cfg.heads_q,
            self.cfg.heads_kv,
            key.1,
            self.cfg.d_head,
            self.cfg.block_size,
        );
        let perf = q.dispatch_with(&mut self.cache).simulate();
        let c = StepCost { time_s: perf.time_s, counters: perf.counters };
        self.decode_memo.insert(key, c);
        c
    }

    /// KV context a request occupies once prefilled + `decoded` tokens.
    fn context_of(&self, req: &ServeRequest, decoded: u32) -> u32 {
        self.cfg.shared_prefix_tokens + req.prompt_tokens + decoded
    }

    /// Cumulative whole-prefill cost at exact context `tokens` (batch
    /// 1, causal). Unbucketed on purpose: chunk costs are differences
    /// of this curve, and bucketing would collapse neighboring chunk
    /// boundaries onto the same point.
    fn cum_prefill(&mut self, tokens: u32) -> StepCost {
        if tokens == 0 {
            return StepCost::default();
        }
        if let Some(&c) = self.chunk_memo.get(&tokens) {
            return c;
        }
        let q = Query::attn(
            self.cfg.arch,
            1,
            self.cfg.heads_q,
            self.cfg.heads_kv,
            tokens,
            self.cfg.d_head,
            true,
        );
        let perf = q.dispatch_with(&mut self.cache).simulate();
        let c = StepCost { time_s: perf.time_s, counters: perf.counters };
        self.chunk_memo.insert(tokens, c);
        c
    }

    /// Price one prefill chunk covering context `[start, end)` as the
    /// cumulative-cost difference `cum(end) - cum(start)`: summed over
    /// a request's chunks this telescopes *exactly* to the whole-prompt
    /// prefill cost, whatever the chunking (asserted in
    /// `tests/serve_sched.rs`).
    fn chunk_cost(&mut self, start: u32, end: u32) -> StepCost {
        let hi = self.cum_prefill(end);
        let lo = self.cum_prefill(start);
        StepCost {
            time_s: (hi.time_s - lo.time_s).max(0.0),
            counters: counters_delta(&hi.counters, &lo.counters),
        }
    }

    /// Simulated cost of the MoE FFN over `tokens` step tokens (zero
    /// when the engine serves a dense model). Memoized by token count —
    /// the grouped dispatch itself is tuned once per shape bucket in
    /// the engine's tune cache. The counter record carries the gate
    /// kernel's top-k softmax traffic on top of the grouped GEMM's.
    fn moe_ffn_step(&mut self, tokens: u32) -> StepCost {
        let Some(m) = self.cfg.moe else {
            return StepCost::default();
        };
        if tokens == 0 {
            return StepCost::default();
        }
        if let Some(&c) = self.moe_memo.get(&tokens) {
            return c;
        }
        let q = Query::moe_gemm(
            self.cfg.arch,
            tokens,
            m.d_model,
            m.d_ff,
            m.experts,
            m.top_k,
            m.skew_pct,
        );
        let perf = q.dispatch_with(&mut self.cache).simulate();
        let gate = router_softmax_counters(
            &MoeConfig::new(m.experts, m.top_k),
            tokens,
        );
        let c = StepCost {
            time_s: perf.time_s,
            counters: perf.counters.merged(&gate),
        };
        self.moe_memo.insert(tokens, c);
        c
    }

    /// Simulated per-chain costs of the membound plane (Add+RMSNorm +
    /// SiLU+Mul) over `tokens` step tokens, fused or force-split per
    /// the config (empty when the plane is off). Memoized by token
    /// count, like the MoE FFN; per-chain so the timeline renders each
    /// chain as its own sub-span.
    fn mb_step(&mut self, tokens: u32) -> Vec<(&'static str, StepCost)> {
        if self.cfg.mb_fusion == MbFusion::Off || tokens == 0 {
            return Vec::new();
        }
        if let Some(c) = self.mb_memo.get(&tokens) {
            return c.clone();
        }
        let d = self.cfg.mb_d_model;
        let mut qs = [
            ("add-rmsnorm", Query::add_rmsnorm(self.cfg.arch, tokens, d)),
            ("silu-mul", Query::silu_mul(self.cfg.arch, tokens, d)),
        ];
        if self.cfg.mb_fusion == MbFusion::Split {
            for (_, q) in &mut qs {
                *q = q.unfused();
            }
        }
        let costs: Vec<(&'static str, StepCost)> = qs
            .iter()
            .map(|(name, q)| {
                let perf = q.dispatch_with(&mut self.cache).simulate();
                (
                    *name,
                    StepCost { time_s: perf.time_s, counters: perf.counters },
                )
            })
            .collect();
        self.mb_memo.insert(tokens, costs.clone());
        costs
    }

    /// One router pass over the step's token batch, folded into the
    /// run's MoE statistics. Seeded by the step ordinal so a replayed
    /// trace routes identically. Returns the assignments this pass
    /// rerouted by capacity overflow (the timeline's router-overflow
    /// instant).
    fn moe_route_step(
        &mut self,
        tokens: u32,
        step: u64,
        stats: &mut MoeServeStats,
    ) -> u32 {
        let Some(m) = self.cfg.moe else {
            return 0;
        };
        if tokens == 0 {
            return 0;
        }
        // only the routing policy matters here: the FFN's width/cost is
        // priced separately by `moe_ffn_step`
        let rc = MoeConfig::new(m.experts, m.top_k)
            .with_skew(m.skew_pct as f64 / 100.0)
            .with_seed(0x5EED ^ step);
        let r = route(&rc, tokens);
        stats.steps += 1;
        stats.mean_imbalance += r.stats.aux_imbalance;
        stats.rerouted += u64::from(r.stats.rerouted);
        stats.dropped_slots += u64::from(r.stats.dropped_slots);
        r.stats.rerouted
    }

    /// Serve a trace to completion on the trace clock.
    pub fn run_trace(&mut self, trace: &[ServeRequest]) -> Result<ServeReport> {
        if trace.is_empty() {
            bail!("empty trace");
        }
        for w in trace.windows(2) {
            if w[1].arrival_s < w[0].arrival_s {
                bail!("trace arrivals must be sorted");
            }
        }
        let prefix = self.cfg.shared_prefix_tokens;
        if prefix > 0 {
            // replicate the system prefix into every GPU's pool
            // (cross-GPU sharing is disabled; pools already holding a
            // replica are skipped)
            self.kv.cache_prefix(SYSTEM_PREFIX, prefix)?;
        }
        // per-trace KV accounting: the manager (and its counters)
        // outlive run_trace, so the report holds deltas from here
        let kv_base = self.kv.stats();

        let mut waiting: VecDeque<usize> = VecDeque::new();
        let mut running: Vec<Running> = Vec::new();
        // highest token index each request has *delivered*; recomputed
        // tokens after a preemption must not re-enter the latency stats
        let mut reached: Vec<u32> = vec![0; trace.len()];
        // per-request Perfetto flow arrows (id = request id): "s" at
        // first admission, "t" at re-admissions / prefill / first
        // decoded token, "f" at completion — so the viewer links each
        // request's journey across lanes and preemptions
        let mut flow_started: Vec<bool> = vec![false; trace.len()];
        // trace time of each request's latest delivered token — ITL for
        // the next one spans prefills and preemption stalls in between
        let mut last_emit: Vec<f64> = vec![0.0; trace.len()];
        let mut next = 0usize;
        let mut now = 0.0f64;
        let mut finished = 0usize;
        let mut ttft = LatencyStats::default();
        let mut itl = LatencyStats::default();
        let mut e2e = LatencyStats::default();
        let mut prefill_steps = 0u64;
        let mut decode_steps = 0u64;
        let mut preemptions = 0u64;
        let mut peak_occ = 0.0f64;
        // tokens of *finished* requests only: preempted-and-recomputed
        // work must not inflate delivered throughput
        let mut delivered_tokens = 0u64;
        let mut moe_stats = MoeServeStats::default();
        let mut mb_stats = MbServeStats::default();
        let n_gpus = self.cfg.n_gpus.max(1) as usize;
        let mut lanes: Vec<GpuLaneStats> =
            (0..n_gpus).map(|_| GpuLaneStats::default()).collect();
        // the timeline is taken out of `self` for the duration of the
        // run so step-cost methods can borrow `self` mutably alongside it
        let mut tl = self.timeline.take();
        let kv_pid = n_gpus as u32;
        if let Some(t) = tl.as_mut() {
            for g in 0..n_gpus as u32 {
                t.meta_process(g, &format!("gpu{g}"));
                t.meta_thread(g, 0, "attn");
                t.meta_thread(g, 1, "ffn+membound");
            }
            t.meta_process(kv_pid, "kv");
        }
        let mut kv_prev = self.kv.stats();

        while finished < trace.len() {
            // fold in everything that has arrived by `now`
            while next < trace.len() && trace[next].arrival_s <= now {
                waiting.push_back(next);
                next += 1;
            }
            if waiting.is_empty() && running.is_empty() {
                if next < trace.len() {
                    now = trace[next].arrival_s;
                    continue;
                }
                bail!("serving stalled with requests unfinished");
            }

            // admission: FIFO onto the least-loaded GPU lane, capacity-
            // and per-lane-batch-gated
            let mut newly: Vec<Vec<usize>> = vec![Vec::new(); n_gpus];
            let mut active: Vec<usize> = vec![0; n_gpus];
            for r in &running {
                active[r.gpu as usize] += 1;
            }
            'admit: loop {
                let Some(&idx) = waiting.front() else {
                    break;
                };
                // load-balancing policy: fewest active sequences, ties
                // to the emptier KV pool, then the lowest GPU id —
                // deterministic, so traces replay bit-identically
                let mut gpu: Option<usize> = None;
                for cand in 0..n_gpus {
                    if active[cand] >= self.cfg.max_batch {
                        continue;
                    }
                    let key = |g: usize| {
                        (active[g], self.kv.pool(g as u32).used_blocks())
                    };
                    let better = match gpu {
                        None => true,
                        Some(best) => key(cand) < key(best),
                    };
                    if better {
                        gpu = Some(cand);
                    }
                }
                let Some(g) = gpu else {
                    break; // every lane is at its batch width
                };
                let gq = g as u32;
                let req = &trace[idx];
                if req.prompt_tokens == 0 {
                    bail!("request {} has an empty prompt", req.id);
                }
                // reject requests that can never fit even alone —
                // admitting one would preempt/re-prefill forever
                let total = self.context_of(req, req.output_tokens.max(1));
                if self.kv.blocks_for(total) + 1 > self.cfg.num_blocks {
                    bail!(
                        "request {} needs {} KV blocks (+1 CoW) but each \
                         GPU's pool holds {}",
                        req.id,
                        self.kv.blocks_for(total),
                        self.cfg.num_blocks,
                    );
                }
                // headroom: prompt + one decode block + a CoW copy
                let need = req.prompt_tokens + 2 * self.cfg.block_size;
                if !self.kv.can_admit_on(gq, need) {
                    break;
                }
                if self.cfg.shared_prefix_tokens > 0 {
                    // the lane's prefix replica may have been evicted
                    // while no live sequence held it; re-pin before
                    // forking — a full pool defers admission, it
                    // doesn't abort
                    if !self.kv.has_prefix_on(gq, SYSTEM_PREFIX)
                        && self
                            .kv
                            .cache_prefix_on(gq, SYSTEM_PREFIX, prefix)
                            .is_err()
                    {
                        break;
                    }
                    if self
                        .kv
                        .fork_from_prefix_on(gq, SYSTEM_PREFIX, req.id)
                        .is_err()
                    {
                        break;
                    }
                    // extend the fork with the request's own prompt
                    for _ in 0..req.prompt_tokens {
                        if self.kv.append_token(req.id).is_err() {
                            self.kv.free_seq(req.id)?;
                            break 'admit;
                        }
                    }
                } else if self.kv.admit_on(gq, req.id, req.prompt_tokens).is_err()
                {
                    break;
                }
                waiting.pop_front();
                active[g] += 1;
                lanes[g].admitted += 1;
                newly[g].push(idx);
                if let Some(t) = tl.as_mut() {
                    t.instant(gq, 0, "serve", "admit", now, vec![(
                        "req".to_string(),
                        Json::Num(req.id as f64),
                    )]);
                    if flow_started[idx] {
                        // re-admission after a preemption continues the
                        // request's existing arrow
                        t.flow_step(gq, 0, "serve", "req", now, req.id);
                    } else {
                        flow_started[idx] = true;
                        t.flow_start(gq, 0, "serve", "req", now, req.id);
                    }
                }
            }
            if let Some(t) = tl.as_mut() {
                let ks = self.kv.stats();
                kv_delta_instants(t, kv_pid, now, &kv_prev, &ks);
                kv_prev = ks;
            }
            peak_occ = peak_occ.max(self.kv.occupancy());
            for (g, lane) in lanes.iter_mut().enumerate() {
                lane.peak_occupancy =
                    lane.peak_occupancy.max(self.kv.occupancy_on(g as u32));
            }

            if newly.iter().any(|lane| !lane.is_empty()) {
                // prefill the admitted batches — every lane prefills its
                // own batch in parallel, so the step costs the slowest
                // lane; completion = each request's first token
                let mut dt = 0.0f64;
                for (g, lane_newly) in newly.iter().enumerate() {
                    if lane_newly.is_empty() {
                        continue;
                    }
                    let batch = lane_newly.len() as u32;
                    let seq = lane_newly
                        .iter()
                        .map(|&i| self.context_of(&trace[i], 0))
                        .max()
                        .expect("non-empty batch");
                    let attn = self.prefill_step(batch, seq);
                    let mut dt_g = attn.time_s;
                    lanes[g].counters.merge(&attn.counters);
                    // the MoE FFN processes every prompt token of the
                    // lane's batch
                    let step_tokens = batch.saturating_mul(seq);
                    let ffn = self.moe_ffn_step(step_tokens);
                    if ffn.time_s > 0.0 {
                        let ordinal = moe_stats.steps;
                        let overflow = self.moe_route_step(
                            step_tokens,
                            ordinal,
                            &mut moe_stats,
                        );
                        moe_stats.ffn_time_s += ffn.time_s;
                        lanes[g].counters.merge(&ffn.counters);
                        if let Some(t) = tl.as_mut() {
                            t.span(
                                g as u32,
                                1,
                                "moe",
                                "moe-ffn",
                                now + dt_g,
                                ffn.time_s,
                                vec![(
                                    "tokens".to_string(),
                                    Json::Num(step_tokens as f64),
                                )],
                            );
                            if overflow > 0 {
                                t.instant(
                                    g as u32,
                                    1,
                                    "moe",
                                    "router-overflow",
                                    now + dt_g,
                                    vec![(
                                        "rerouted".to_string(),
                                        Json::Num(overflow as f64),
                                    )],
                                );
                            }
                        }
                        dt_g += ffn.time_s;
                    }
                    // membound chains over every prompt token
                    let mb = self.mb_step(step_tokens);
                    if !mb.is_empty() {
                        let mb_total: f64 =
                            mb.iter().map(|(_, c)| c.time_s).sum();
                        mb_stats.steps += 1;
                        mb_stats.time_s += mb_total;
                        let mut cursor = now + dt_g;
                        for (name, c) in &mb {
                            lanes[g].counters.merge(&c.counters);
                            if let Some(t) = tl.as_mut() {
                                t.span(
                                    g as u32,
                                    1,
                                    "membound",
                                    name,
                                    cursor,
                                    c.time_s,
                                    vec![],
                                );
                            }
                            cursor += c.time_s;
                        }
                        dt_g += mb_total;
                    }
                    if let Some(t) = tl.as_mut() {
                        t.span(g as u32, 0, "serve", "prefill", now, dt_g, vec![
                            ("batch".to_string(), Json::Num(batch as f64)),
                            ("seq".to_string(), Json::Num(seq as f64)),
                        ]);
                        for &idx in lane_newly {
                            t.flow_step(
                                g as u32,
                                0,
                                "serve",
                                "req",
                                now,
                                trace[idx].id,
                            );
                        }
                    }
                    dt = dt.max(dt_g);
                }
                now += dt;
                prefill_steps += 1;
                for (g, lane_newly) in newly.iter().enumerate() {
                    for &idx in lane_newly {
                        let req = &trace[idx];
                        if reached[idx] == 0 {
                            // first prefill; a re-prefill after preemption
                            // recomputes an already-delivered token
                            ttft.record_s(now - req.arrival_s);
                            reached[idx] = 1;
                            last_emit[idx] = now;
                        }
                        if req.output_tokens <= 1 {
                            self.kv.free_seq(req.id)?;
                            e2e.record_s(now - req.arrival_s);
                            delivered_tokens +=
                                u64::from(req.output_tokens.max(1));
                            finished += 1;
                            if let Some(t) = tl.as_mut() {
                                t.flow_end(
                                    g as u32,
                                    0,
                                    "serve",
                                    "req",
                                    now,
                                    req.id,
                                );
                            }
                        } else {
                            running.push(Running {
                                idx,
                                decoded: 1,
                                gpu: g as u32,
                            });
                        }
                    }
                }
                continue;
            }

            if running.is_empty() {
                // admission blocked with nothing running: the head
                // request can never fit
                let idx = *waiting.front().expect("non-empty waiting");
                bail!(
                    "request {} needs more KV than the pool holds \
                     ({} blocks of {} tokens)",
                    trace[idx].id,
                    self.cfg.num_blocks,
                    self.cfg.block_size,
                );
            }

            // one decode step: every lane decodes its own running batch
            // in parallel, so the step costs the slowest lane
            let mut dt = 0.0f64;
            for g in 0..n_gpus {
                let lane: Vec<&Running> =
                    running.iter().filter(|r| r.gpu == g as u32).collect();
                if lane.is_empty() {
                    continue;
                }
                let batch = lane.len() as u32;
                let ctx = lane
                    .iter()
                    .map(|r| self.context_of(&trace[r.idx], r.decoded))
                    .max()
                    .expect("non-empty lane");
                let attn = self.decode_step(batch, ctx);
                let mut dt_g = attn.time_s;
                lanes[g].counters.merge(&attn.counters);
                // decode emits one token per running sequence: route the
                // lane's batch and pay the grouped FFN on the step clock
                let ffn = self.moe_ffn_step(batch);
                if ffn.time_s > 0.0 {
                    let ordinal = moe_stats.steps;
                    let overflow =
                        self.moe_route_step(batch, ordinal, &mut moe_stats);
                    moe_stats.ffn_time_s += ffn.time_s;
                    lanes[g].counters.merge(&ffn.counters);
                    if let Some(t) = tl.as_mut() {
                        t.span(
                            g as u32,
                            1,
                            "moe",
                            "moe-ffn",
                            now + dt_g,
                            ffn.time_s,
                            vec![(
                                "tokens".to_string(),
                                Json::Num(batch as f64),
                            )],
                        );
                        if overflow > 0 {
                            t.instant(
                                g as u32,
                                1,
                                "moe",
                                "router-overflow",
                                now + dt_g,
                                vec![(
                                    "rerouted".to_string(),
                                    Json::Num(overflow as f64),
                                )],
                            );
                        }
                    }
                    dt_g += ffn.time_s;
                }
                // membound chains over the lane's emitted tokens
                let mb = self.mb_step(batch);
                if !mb.is_empty() {
                    let mb_total: f64 = mb.iter().map(|(_, c)| c.time_s).sum();
                    mb_stats.steps += 1;
                    mb_stats.time_s += mb_total;
                    let mut cursor = now + dt_g;
                    for (name, c) in &mb {
                        lanes[g].counters.merge(&c.counters);
                        if let Some(t) = tl.as_mut() {
                            t.span(
                                g as u32,
                                1,
                                "membound",
                                name,
                                cursor,
                                c.time_s,
                                vec![],
                            );
                        }
                        cursor += c.time_s;
                    }
                    dt_g += mb_total;
                }
                if let Some(t) = tl.as_mut() {
                    t.span(g as u32, 0, "serve", "decode", now, dt_g, vec![
                        ("batch".to_string(), Json::Num(batch as f64)),
                        ("ctx".to_string(), Json::Num(ctx as f64)),
                    ]);
                }
                dt = dt.max(dt_g);
            }
            now += dt;
            decode_steps += 1;

            let mut still = Vec::with_capacity(running.len());
            for mut r in running.drain(..) {
                let req = &trace[r.idx];
                r.decoded += 1;
                lanes[r.gpu as usize].decode_tokens += 1;
                if r.decoded > reached[r.idx] {
                    // a newly delivered token: its inter-token gap
                    // spans any prefill steps and preemption stalls
                    // since the previous delivery, not just `dt`
                    itl.record_s(now - last_emit[r.idx]);
                    reached[r.idx] = r.decoded;
                    last_emit[r.idx] = now;
                }
                if r.decoded == 2 {
                    // first decoded token (again after each preemption):
                    // route the request's arrow through the decode lane
                    if let Some(t) = tl.as_mut() {
                        t.flow_step(r.gpu, 0, "serve", "req", now, req.id);
                    }
                }
                if r.decoded >= req.output_tokens.max(1) {
                    self.kv.free_seq(req.id)?;
                    e2e.record_s(now - req.arrival_s);
                    delivered_tokens += u64::from(req.output_tokens.max(1));
                    finished += 1;
                    if let Some(t) = tl.as_mut() {
                        t.flow_end(r.gpu, 0, "serve", "req", now, req.id);
                    }
                    continue;
                }
                match self.kv.append_token(req.id) {
                    Ok(()) => still.push(r),
                    Err(_) => {
                        // pool exhausted: preempt and recompute later
                        self.kv.free_seq(req.id)?;
                        preemptions += 1;
                        if let Some(t) = tl.as_mut() {
                            t.instant(r.gpu, 0, "serve", "preempt", now, vec![
                                ("req".to_string(), Json::Num(req.id as f64)),
                            ]);
                        }
                        waiting.push_front(r.idx);
                    }
                }
            }
            running = still;
            if let Some(t) = tl.as_mut() {
                let ks = self.kv.stats();
                kv_delta_instants(t, kv_pid, now, &kv_prev, &ks);
                kv_prev = ks;
            }
            peak_occ = peak_occ.max(self.kv.occupancy());
            for (g, lane) in lanes.iter_mut().enumerate() {
                lane.peak_occupancy =
                    lane.peak_occupancy.max(self.kv.occupancy_on(g as u32));
            }
        }

        self.timeline = tl;
        // run counters = the in-order sum of the lane counters, so the
        // lane-sum invariant holds bit-exactly by construction
        let mut run_counters = KernelCounters::default();
        for lane in &lanes {
            run_counters.merge(&lane.counters);
        }
        let makespan = now - trace[0].arrival_s;
        Ok(ServeReport {
            served: trace.len() as u64,
            preemptions,
            prefill_steps,
            decode_steps,
            makespan_s: makespan,
            throughput_tok_s: delivered_tokens as f64 / makespan.max(1e-9),
            ttft,
            itl,
            e2e,
            peak_occupancy: peak_occ,
            counters: run_counters,
            kv: self.kv.stats().since(&kv_base),
            moe: self.cfg.moe.map(|_| {
                let mut m = moe_stats;
                if m.steps > 0 {
                    m.mean_imbalance /= m.steps as f64;
                }
                m
            }),
            membound: (self.cfg.mb_fusion != MbFusion::Off)
                .then_some(mb_stats),
            n_gpus: self.cfg.n_gpus,
            per_gpu: lanes,
            per_tenant: Vec::new(),
            sched: None,
        })
    }

    /// Serve a multi-tenant trace. With `cfg.sched = None` this *is*
    /// the legacy lock-step engine on the folded requests (each
    /// tenant's prefix re-prefilled as ordinary prompt tokens on every
    /// admission) — bit-identical to [`Self::run_trace`], asserted in
    /// `tests/serve_sched.rs`. With a scheduler configured it runs the
    /// chunked-prefill, prefix-aware, SLO-ordered scheduled loop.
    pub fn run_traced(
        &mut self,
        trace: &[TracedRequest],
    ) -> Result<ServeReport> {
        match self.cfg.sched {
            None => {
                let folded: Vec<ServeRequest> =
                    trace.iter().map(|t| t.folded()).collect();
                self.run_trace(&folded)
            }
            Some(sc) => self.run_scheduled(trace, &sc),
        }
    }

    /// The scheduled serving loop: chunked prefill against a per-lane
    /// token budget, prefix-aware routing, idle-lane stealing, SLO
    /// admission order, and (optionally) disaggregated prefill/decode
    /// with the KV handoff priced on the configured link.
    fn run_scheduled(
        &mut self,
        trace: &[TracedRequest],
        sc: &SchedConfig,
    ) -> Result<ServeReport> {
        if trace.is_empty() {
            bail!("empty trace");
        }
        for w in trace.windows(2) {
            if w[1].req.arrival_s < w[0].req.arrival_s {
                bail!("trace arrivals must be sorted");
            }
        }
        if self.cfg.shared_prefix_tokens > 0 {
            bail!(
                "scheduled serving uses per-tenant trace prefixes; set \
                 shared_prefix_tokens = 0"
            );
        }
        if sc.step_tokens == 0 || sc.chunk_tokens == 0 {
            bail!("scheduler needs nonzero step_tokens/chunk_tokens");
        }
        let n_gpus = self.cfg.n_gpus.max(1) as usize;
        if (sc.step_tokens as usize) < self.cfg.max_batch {
            bail!("step_tokens must cover the decode batch width");
        }
        if let Some(d) = sc.disagg {
            if d.prefill_gpus == 0 || d.prefill_gpus as usize >= n_gpus {
                bail!(
                    "disaggregation needs 1..n_gpus-1 prefill GPUs, got {} \
                     of {}",
                    d.prefill_gpus,
                    n_gpus
                );
            }
        }
        let is_prefill_lane = |g: usize| match sc.disagg {
            None => true,
            Some(d) => g < d.prefill_gpus as usize,
        };
        let is_decode_lane = |g: usize| match sc.disagg {
            None => true,
            Some(d) => g >= d.prefill_gpus as usize,
        };
        let kv_base = self.kv.stats();

        let mut queues = LaneQueues::new(n_gpus);
        let mut prefilling: Vec<Prefilling> = Vec::new();
        // disagg only: prefilled sequences awaiting their KV handoff
        let mut ready: VecDeque<(usize, u32)> = VecDeque::new();
        let mut running: Vec<Running> = Vec::new();
        let mut reached: Vec<u32> = vec![0; trace.len()];
        let mut last_emit: Vec<f64> = vec![0.0; trace.len()];
        let mut flow_started: Vec<bool> = vec![false; trace.len()];
        let mut next = 0usize;
        let mut now = 0.0f64;
        let mut finished = 0usize;
        let mut ttft = LatencyStats::default();
        let mut itl = LatencyStats::default();
        let mut e2e = LatencyStats::default();
        let mut prefill_steps = 0u64;
        let mut decode_steps = 0u64;
        let mut preemptions = 0u64;
        let mut peak_occ = 0.0f64;
        let mut delivered_tokens = 0u64;
        let mut moe_stats = MoeServeStats::default();
        let mut mb_stats = MbServeStats::default();
        let mut sched_stats = SchedServeStats::default();
        let mut lanes: Vec<GpuLaneStats> =
            (0..n_gpus).map(|_| GpuLaneStats::default()).collect();
        let n_tenants =
            trace.iter().map(|t| t.tenant).max().unwrap_or(0) as usize + 1;
        let mut tenants: Vec<TenantLatencyStats> = (0..n_tenants)
            .map(|t| TenantLatencyStats {
                tenant: t as u32,
                ..TenantLatencyStats::default()
            })
            .collect();
        for t in trace {
            let acc = &mut tenants[t.tenant as usize];
            acc.slo = t.slo.tag();
            acc.requests += 1;
        }

        let mut tl = self.timeline.take();
        let kv_pid = n_gpus as u32;
        if let Some(t) = tl.as_mut() {
            for g in 0..n_gpus {
                let role = match (is_prefill_lane(g), is_decode_lane(g)) {
                    (true, true) => "gpu",
                    (true, false) => "prefill-gpu",
                    _ => "decode-gpu",
                };
                t.meta_process(g as u32, &format!("{role}{g}"));
                t.meta_thread(g as u32, 0, "attn");
                t.meta_thread(g as u32, 1, "ffn+membound");
            }
            t.meta_process(kv_pid, "kv");
            t.meta_thread(kv_pid, 1, "handoff");
        }
        let mut kv_prev = self.kv.stats();

        // KV residents per lane (prefilling + awaiting-handoff +
        // running), the batch-slot currency of admission and handoff
        let resident_of = |prefilling: &[Prefilling],
                           ready: &VecDeque<(usize, u32)>,
                           running: &[Running]| {
            let mut res = vec![0usize; n_gpus];
            for p in prefilling {
                res[p.gpu as usize] += 1;
            }
            for &(_, src) in ready {
                res[src as usize] += 1;
            }
            for r in running {
                res[r.gpu as usize] += 1;
            }
            res
        };

        while finished < trace.len() {
            let mut resident = resident_of(&prefilling, &ready, &running);
            // fold in everything that has arrived by `now`, routing each
            // request to a prefill lane: the lane already pinning its
            // tenant prefix when prefix-aware, else the least-loaded
            while next < trace.len() && trace[next].req.arrival_s <= now {
                let t = &trace[next];
                if t.req.prompt_tokens == 0 {
                    bail!("request {} has an empty prompt", t.req.id);
                }
                let total = t.prefix_tokens
                    + t.req.prompt_tokens
                    + t.req.output_tokens.max(1);
                if self.kv.blocks_for(total) + 1 > self.cfg.num_blocks {
                    bail!(
                        "request {} needs {} KV blocks (+1 CoW) but each \
                         GPU's pool holds {}",
                        t.req.id,
                        self.kv.blocks_for(total),
                        self.cfg.num_blocks,
                    );
                }
                let lane =
                    self.route_lane(t, sc, &queues, &resident, &is_prefill_lane);
                queues.push(lane, next);
                next += 1;
            }
            if queues.is_empty()
                && prefilling.is_empty()
                && ready.is_empty()
                && running.is_empty()
            {
                if next < trace.len() {
                    now = now.max(trace[next].req.arrival_s);
                    continue;
                }
                bail!("serving stalled with requests unfinished");
            }

            // idle prefill lanes steal the head of the longest queue
            if sc.stealing {
                for g in 0..n_gpus {
                    if is_prefill_lane(g)
                        && queues.len(g) == 0
                        && resident[g] < self.cfg.max_batch
                        && !prefilling.iter().any(|p| p.gpu as usize == g)
                    {
                        queues.steal_into(g);
                    }
                }
            }
            // SLO admission order within each lane's queue
            if sc.slo_priority {
                for g in 0..n_gpus {
                    if queues.len(g) > 1 {
                        queues.order_by(g, |i| {
                            (
                                std::cmp::Reverse(trace[i].slo.priority()),
                                i,
                            )
                        });
                    }
                }
            }

            // admission: each prefill lane drains its queue while KV
            // headroom and batch slots last
            let mut admitted_any = false;
            for g in 0..n_gpus {
                if !is_prefill_lane(g) {
                    continue;
                }
                let gq = g as u32;
                while let Some(idx) = queues.front(g) {
                    if resident[g] >= self.cfg.max_batch {
                        break;
                    }
                    let t = &trace[idx];
                    let use_prefix = sc.prefix_aware && t.prefix_tokens > 0;
                    let (target, base) = if use_prefix {
                        let hit = self.kv.has_prefix_on(gq, t.prefix_id);
                        let mut need =
                            t.req.prompt_tokens + 2 * self.cfg.block_size;
                        if !hit {
                            need += t.prefix_tokens;
                        }
                        if !self.kv.can_admit_on(gq, need) {
                            break;
                        }
                        if !hit
                            && self
                                .kv
                                .cache_prefix_on(
                                    gq,
                                    t.prefix_id,
                                    t.prefix_tokens,
                                )
                                .is_err()
                        {
                            break;
                        }
                        if self
                            .kv
                            .fork_from_prefix_on(gq, t.prefix_id, t.req.id)
                            .is_err()
                        {
                            break;
                        }
                        let mut ok = true;
                        for _ in 0..t.req.prompt_tokens {
                            if self.kv.append_token(t.req.id).is_err() {
                                self.kv.free_seq(t.req.id)?;
                                ok = false;
                                break;
                            }
                        }
                        if !ok {
                            break;
                        }
                        if hit {
                            sched_stats.prefix_hits += 1;
                            // the prefix KV is resident: compute only
                            // the request's own prompt
                            (t.req.prompt_tokens, t.prefix_tokens)
                        } else {
                            sched_stats.prefix_misses += 1;
                            (t.cold_prompt_tokens(), 0)
                        }
                    } else {
                        let need = t.cold_prompt_tokens()
                            + 2 * self.cfg.block_size;
                        if !self.kv.can_admit_on(gq, need) {
                            break;
                        }
                        if self
                            .kv
                            .admit_on(gq, t.req.id, t.cold_prompt_tokens())
                            .is_err()
                        {
                            break;
                        }
                        (t.cold_prompt_tokens(), 0)
                    };
                    queues.pop(g);
                    resident[g] += 1;
                    lanes[g].admitted += 1;
                    admitted_any = true;
                    sched_stats.chunk_tokens += u64::from(target);
                    prefilling.push(Prefilling {
                        idx,
                        gpu: gq,
                        done: 0,
                        target,
                        base,
                    });
                    if let Some(tr) = tl.as_mut() {
                        tr.instant(gq, 0, "serve", "admit", now, vec![(
                            "req".to_string(),
                            Json::Num(t.req.id as f64),
                        )]);
                        if flow_started[idx] {
                            tr.flow_step(gq, 0, "serve", "req", now, t.req.id);
                        } else {
                            flow_started[idx] = true;
                            tr.flow_start(gq, 0, "serve", "req", now, t.req.id);
                        }
                    }
                }
            }
            if let Some(t) = tl.as_mut() {
                let ks = self.kv.stats();
                kv_delta_instants(t, kv_pid, now, &kv_prev, &ks);
                kv_prev = ks;
            }
            peak_occ = peak_occ.max(self.kv.occupancy());
            for (g, lane) in lanes.iter_mut().enumerate() {
                lane.peak_occupancy =
                    lane.peak_occupancy.max(self.kv.occupancy_on(g as u32));
            }

            // one scheduled step: every lane decodes its running batch
            // and spends its leftover token budget on prefill chunks,
            // in parallel across lanes (the step costs the slowest)
            let mut dt = 0.0f64;
            let mut any_decode = false;
            let mut any_chunk = false;
            for g in 0..n_gpus {
                let gq = g as u32;
                let mut dt_g = 0.0f64;
                let mut lane_tokens = 0u32;
                // decode half
                let lane: Vec<(usize, u32)> = running
                    .iter()
                    .filter(|r| r.gpu == gq)
                    .map(|r| (r.idx, r.decoded))
                    .collect();
                if !lane.is_empty() {
                    let batch = lane.len() as u32;
                    let ctx = lane
                        .iter()
                        .map(|&(idx, d)| {
                            let t = &trace[idx];
                            t.prefix_tokens + t.req.prompt_tokens + d
                        })
                        .max()
                        .expect("non-empty lane");
                    let attn = self.decode_step(batch, ctx);
                    lanes[g].counters.merge(&attn.counters);
                    if let Some(t) = tl.as_mut() {
                        t.span(gq, 0, "serve", "decode", now, attn.time_s, vec![
                            ("batch".to_string(), Json::Num(batch as f64)),
                            ("ctx".to_string(), Json::Num(ctx as f64)),
                        ]);
                    }
                    dt_g += attn.time_s;
                    lane_tokens += batch;
                    any_decode = true;
                }
                // prefill chunks with the leftover budget
                let mut budget = sc.step_tokens.saturating_sub(lane_tokens);
                let mut chunk_time = 0.0f64;
                let mut chunk_tokens = 0u32;
                let mut chunked = 0u64;
                let mut progress = true;
                while budget > 0 && progress {
                    progress = false;
                    for p in prefilling.iter_mut() {
                        if p.gpu != gq || p.done >= p.target {
                            continue;
                        }
                        let c =
                            chunk_len(p.target - p.done, sc.chunk_tokens, budget);
                        if c == 0 {
                            continue;
                        }
                        let cost =
                            self.chunk_cost(p.base + p.done, p.base + p.done + c);
                        lanes[g].counters.merge(&cost.counters);
                        chunk_time += cost.time_s;
                        chunk_tokens += c;
                        chunked += 1;
                        budget -= c;
                        p.done += c;
                        progress = true;
                        if budget == 0 {
                            break;
                        }
                    }
                }
                if chunked > 0 {
                    sched_stats.chunks += chunked;
                    any_chunk = true;
                    if let Some(t) = tl.as_mut() {
                        t.span(
                            gq,
                            0,
                            "serve",
                            "prefill-chunks",
                            now + dt_g,
                            chunk_time,
                            vec![
                                (
                                    "chunks".to_string(),
                                    Json::Num(chunked as f64),
                                ),
                                (
                                    "tokens".to_string(),
                                    Json::Num(chunk_tokens as f64),
                                ),
                            ],
                        );
                    }
                    dt_g += chunk_time;
                    lane_tokens += chunk_tokens;
                }
                // MoE FFN + membound chains over the lane's step tokens
                let ffn = self.moe_ffn_step(lane_tokens);
                if ffn.time_s > 0.0 {
                    let ordinal = moe_stats.steps;
                    let overflow = self.moe_route_step(
                        lane_tokens,
                        ordinal,
                        &mut moe_stats,
                    );
                    moe_stats.ffn_time_s += ffn.time_s;
                    lanes[g].counters.merge(&ffn.counters);
                    if let Some(t) = tl.as_mut() {
                        t.span(gq, 1, "moe", "moe-ffn", now + dt_g, ffn.time_s, vec![
                            (
                                "tokens".to_string(),
                                Json::Num(lane_tokens as f64),
                            ),
                        ]);
                        if overflow > 0 {
                            t.instant(
                                gq,
                                1,
                                "moe",
                                "router-overflow",
                                now + dt_g,
                                vec![(
                                    "rerouted".to_string(),
                                    Json::Num(overflow as f64),
                                )],
                            );
                        }
                    }
                    dt_g += ffn.time_s;
                }
                let mb = self.mb_step(lane_tokens);
                if !mb.is_empty() {
                    let mb_total: f64 = mb.iter().map(|(_, c)| c.time_s).sum();
                    mb_stats.steps += 1;
                    mb_stats.time_s += mb_total;
                    let mut cursor = now + dt_g;
                    for (name, c) in &mb {
                        lanes[g].counters.merge(&c.counters);
                        if let Some(t) = tl.as_mut() {
                            t.span(gq, 1, "membound", name, cursor, c.time_s, vec![]);
                        }
                        cursor += c.time_s;
                    }
                    dt_g += mb_total;
                }
                dt = dt.max(dt_g);
            }
            now += dt;
            if any_chunk {
                prefill_steps += 1;
            }
            if any_decode {
                decode_steps += 1;
            }

            // decode bookkeeping: emitted tokens, finishes, preemptions
            let mut still = Vec::with_capacity(running.len());
            let mut finished_any = false;
            for mut r in running.drain(..) {
                let t = &trace[r.idx];
                let req = &t.req;
                r.decoded += 1;
                lanes[r.gpu as usize].decode_tokens += 1;
                if r.decoded > reached[r.idx] {
                    // a newly delivered token: recomputed tokens after
                    // a preemption never re-enter the latency stats
                    itl.record_s(now - last_emit[r.idx]);
                    tenants[t.tenant as usize]
                        .itl
                        .record_s(now - last_emit[r.idx]);
                    reached[r.idx] = r.decoded;
                    last_emit[r.idx] = now;
                }
                if r.decoded == 2 {
                    if let Some(tr) = tl.as_mut() {
                        tr.flow_step(r.gpu, 0, "serve", "req", now, req.id);
                    }
                }
                if r.decoded >= req.output_tokens.max(1) {
                    self.kv.free_seq(req.id)?;
                    e2e.record_s(now - req.arrival_s);
                    delivered_tokens += u64::from(req.output_tokens.max(1));
                    finished += 1;
                    finished_any = true;
                    tenants[t.tenant as usize].served += 1;
                    if let Some(tr) = tl.as_mut() {
                        tr.flow_end(r.gpu, 0, "serve", "req", now, req.id);
                    }
                    continue;
                }
                match self.kv.append_token(req.id) {
                    Ok(()) => still.push(r),
                    Err(_) => {
                        // pool exhausted: preempt, re-route, recompute
                        self.kv.free_seq(req.id)?;
                        preemptions += 1;
                        if let Some(tr) = tl.as_mut() {
                            tr.instant(r.gpu, 0, "serve", "preempt", now, vec![
                                ("req".to_string(), Json::Num(req.id as f64)),
                            ]);
                        }
                        let res = resident_of(&prefilling, &ready, &still);
                        let lane = self.route_lane(
                            t,
                            sc,
                            &queues,
                            &res,
                            &is_prefill_lane,
                        );
                        queues.push_front(lane, r.idx);
                    }
                }
            }
            running = still;

            // prefill completions: TTFT on the first completion, then
            // decode (colocated) or the handoff queue (disaggregated)
            let mut keep = Vec::with_capacity(prefilling.len());
            for p in prefilling.drain(..) {
                if p.done < p.target {
                    keep.push(p);
                    continue;
                }
                let t = &trace[p.idx];
                let req = &t.req;
                if reached[p.idx] == 0 {
                    ttft.record_s(now - req.arrival_s);
                    tenants[t.tenant as usize]
                        .ttft
                        .record_s(now - req.arrival_s);
                    reached[p.idx] = 1;
                    last_emit[p.idx] = now;
                }
                if let Some(tr) = tl.as_mut() {
                    tr.flow_step(p.gpu, 0, "serve", "req", now, req.id);
                }
                if req.output_tokens <= 1 {
                    self.kv.free_seq(req.id)?;
                    e2e.record_s(now - req.arrival_s);
                    delivered_tokens += u64::from(req.output_tokens.max(1));
                    finished += 1;
                    finished_any = true;
                    tenants[t.tenant as usize].served += 1;
                    if let Some(tr) = tl.as_mut() {
                        tr.flow_end(p.gpu, 0, "serve", "req", now, req.id);
                    }
                } else if sc.disagg.is_some() {
                    ready.push_back((p.idx, p.gpu));
                } else {
                    running.push(Running {
                        idx: p.idx,
                        decoded: 1,
                        gpu: p.gpu,
                    });
                }
            }
            prefilling = keep;

            // disaggregated handoffs: move each ready sequence's KV to
            // a decode pool, serialized on the link and priced by it
            let mut handed_any = false;
            if let Some(d) = sc.disagg {
                let mut deferred: VecDeque<(usize, u32)> = VecDeque::new();
                let mut cursor = now;
                while let Some((idx, src)) = ready.pop_front() {
                    let res = resident_of(&prefilling, &ready, &running);
                    let t = &trace[idx];
                    let ctx_tokens = t.prefix_tokens + t.req.prompt_tokens;
                    let need = ctx_tokens + 2 * self.cfg.block_size;
                    let dst = (0..n_gpus)
                        .filter(|&g| {
                            is_decode_lane(g)
                                && res[g] < self.cfg.max_batch
                                && self.kv.can_admit_on(g as u32, need)
                        })
                        .min_by_key(|&g| (res[g], g));
                    let Some(dg) = dst else {
                        // no decode slot yet: retry after the next step
                        deferred.push_back((idx, src));
                        continue;
                    };
                    let bytes = self.kv.blocks_for(ctx_tokens) as f64
                        * self.cfg.kv_block_bytes();
                    let t_h = d.link.point_to_point_s(bytes);
                    self.kv.free_seq(t.req.id)?;
                    self.kv.admit_on(dg as u32, t.req.id, ctx_tokens)?;
                    sched_stats.handoffs += 1;
                    sched_stats.handoff_bytes += bytes;
                    sched_stats.handoff_s += t_h;
                    lanes[dg].counters.merge(&KernelCounters {
                        cross_gpu_bytes: bytes,
                        ..KernelCounters::default()
                    });
                    if let Some(tr) = tl.as_mut() {
                        tr.flow_step(src, 0, "serve", "req", cursor, t.req.id);
                        tr.span(
                            kv_pid,
                            1,
                            "kv",
                            "kv-handoff",
                            cursor,
                            t_h,
                            vec![
                                (
                                    "req".to_string(),
                                    Json::Num(t.req.id as f64),
                                ),
                                ("bytes".to_string(), Json::Num(bytes)),
                                (
                                    "src".to_string(),
                                    Json::Num(src as f64),
                                ),
                                ("dst".to_string(), Json::Num(dg as f64)),
                            ],
                        );
                        tr.flow_step(
                            dg as u32,
                            0,
                            "serve",
                            "req",
                            cursor + t_h,
                            t.req.id,
                        );
                    }
                    cursor += t_h;
                    handed_any = true;
                    running.push(Running {
                        idx,
                        decoded: 1,
                        gpu: dg as u32,
                    });
                }
                ready = deferred;
                now = cursor;
            }

            if let Some(t) = tl.as_mut() {
                let ks = self.kv.stats();
                kv_delta_instants(t, kv_pid, now, &kv_prev, &ks);
                kv_prev = ks;
            }
            peak_occ = peak_occ.max(self.kv.occupancy());
            for (g, lane) in lanes.iter_mut().enumerate() {
                lane.peak_occupancy =
                    lane.peak_occupancy.max(self.kv.occupancy_on(g as u32));
            }

            // progress guard: a step that admitted nothing, computed
            // nothing, handed nothing off and finished nothing can only
            // be waiting on future arrivals
            if !admitted_any
                && !any_decode
                && !any_chunk
                && !handed_any
                && !finished_any
            {
                // only a future arrival can unblock an idle step; if the
                // next arrival is already due, nothing ever will
                if next < trace.len() && trace[next].req.arrival_s > now {
                    now = trace[next].req.arrival_s;
                    continue;
                }
                bail!("serving stalled with requests unfinished");
            }
        }

        self.timeline = tl;
        sched_stats.stolen = queues.stolen;
        let mut run_counters = KernelCounters::default();
        for lane in &lanes {
            run_counters.merge(&lane.counters);
        }
        let makespan = now - trace[0].req.arrival_s;
        tenants.retain(|t| t.requests > 0);
        Ok(ServeReport {
            served: trace.len() as u64,
            preemptions,
            prefill_steps,
            decode_steps,
            makespan_s: makespan,
            throughput_tok_s: delivered_tokens as f64 / makespan.max(1e-9),
            ttft,
            itl,
            e2e,
            peak_occupancy: peak_occ,
            counters: run_counters,
            kv: self.kv.stats().since(&kv_base),
            moe: self.cfg.moe.map(|_| {
                let mut m = moe_stats;
                if m.steps > 0 {
                    m.mean_imbalance /= m.steps as f64;
                }
                m
            }),
            membound: (self.cfg.mb_fusion != MbFusion::Off)
                .then_some(mb_stats),
            n_gpus: self.cfg.n_gpus,
            per_gpu: lanes,
            per_tenant: tenants,
            sched: Some(sched_stats),
        })
    }

    /// The routing policy: among prefill lanes, prefer one already
    /// pinning the request's tenant prefix (prefix-aware mode); break
    /// ties — and fall back — to the least-loaded lane by (queued +
    /// resident sequences, used KV blocks, lane id). Deterministic, so
    /// scheduled traces replay bit-identically.
    fn route_lane(
        &self,
        t: &TracedRequest,
        sc: &SchedConfig,
        queues: &LaneQueues,
        resident: &[usize],
        is_prefill_lane: &dyn Fn(usize) -> bool,
    ) -> usize {
        let n_gpus = resident.len();
        let load = |g: usize| {
            (
                queues.len(g) + resident[g],
                self.kv.pool(g as u32).used_blocks(),
                g,
            )
        };
        if sc.prefix_aware && t.prefix_tokens > 0 {
            if let Some(g) = (0..n_gpus)
                .filter(|&g| {
                    is_prefill_lane(g)
                        && self.kv.has_prefix_on(g as u32, t.prefix_id)
                })
                .min_by_key(|&g| load(g))
            {
                return g;
            }
        }
        (0..n_gpus)
            .filter(|&g| is_prefill_lane(g))
            .min_by_key(|&g| load(g))
            .expect("at least one prefill lane")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_trace_is_sorted_and_bounded() {
        let tr = serve_trace(64, 100.0, 3);
        assert_eq!(tr.len(), 64);
        for w in tr.windows(2) {
            assert!(w[1].arrival_s >= w[0].arrival_s);
        }
        for r in &tr {
            assert!((64..=512).contains(&r.prompt_tokens), "{}", r.prompt_tokens);
            assert!((16..=128).contains(&r.output_tokens), "{}", r.output_tokens);
        }
    }

    #[test]
    fn small_trace_completes() {
        let mut eng = ServeEngine::new(ServeConfig::default()).unwrap();
        let trace = serve_trace(16, 100.0, 5);
        let rep = eng.run_trace(&trace).unwrap();
        assert_eq!(rep.served, 16);
        assert_eq!(rep.ttft.count(), 16);
        assert_eq!(rep.e2e.count(), 16);
        assert!(rep.decode_steps > 0 && rep.prefill_steps > 0);
        assert!(rep.makespan_s > 0.0);
        assert!(rep.peak_occupancy > 0.0 && rep.peak_occupancy <= 1.0);
        // all KV returned once the trace drains (the pinned system
        // prefix is the only resident allocation)
        let prefix_blocks =
            eng.kv().blocks_for(ServeConfig::default().shared_prefix_tokens);
        assert_eq!(eng.kv().used_blocks(), prefix_blocks as usize);
        eng.kv().validate().unwrap();
    }

    #[test]
    fn tiny_pool_preempts_but_finishes() {
        let cfg = ServeConfig {
            num_blocks: 96,
            max_batch: 8,
            shared_prefix_tokens: 32,
            ..ServeConfig::default()
        };
        let mut eng = ServeEngine::new(cfg).unwrap();
        let trace = serve_trace(24, 500.0, 9);
        let rep = eng.run_trace(&trace).unwrap();
        assert_eq!(rep.served, 24);
        eng.kv().validate().unwrap();
    }

    #[test]
    fn moe_model_adds_ffn_time_but_not_kv_pressure() {
        let trace = serve_trace(12, 300.0, 21);
        let dense_cfg = ServeConfig { max_batch: 8, ..ServeConfig::default() };
        let moe_cfg = ServeConfig {
            moe: Some(MoeServeConfig::default()),
            ..dense_cfg.clone()
        };
        let mut dense = ServeEngine::new(dense_cfg).unwrap();
        let mut moe = ServeEngine::new(moe_cfg).unwrap();
        let dr = dense.run_trace(&trace).unwrap();
        let mr = moe.run_trace(&trace).unwrap();
        assert_eq!(mr.served, 12);
        // the FFN rides the step clock: every step got slower
        assert!(mr.makespan_s > dr.makespan_s, "{} !> {}", mr.makespan_s, dr.makespan_s);
        let stats = mr.moe.as_ref().expect("moe stats present");
        assert_eq!(stats.steps, mr.prefill_steps + mr.decode_steps);
        assert!(stats.ffn_time_s > 0.0);
        assert!(stats.mean_imbalance > 0.5, "{}", stats.mean_imbalance);
        assert!(dr.moe.is_none());
        // KV plane untouched: the MoE engine finishes the same trace
        // without extra preemption pressure
        assert_eq!(mr.preemptions, dr.preemptions);
        // and the payload is deterministic across replays
        let mut again = ServeEngine::new(ServeConfig {
            moe: Some(MoeServeConfig::default()),
            max_batch: 8,
            ..ServeConfig::default()
        })
        .unwrap();
        let rep2 = again.run_trace(&trace).unwrap();
        assert_eq!(mr.to_json().dump(), rep2.to_json().dump());
    }

    #[test]
    fn fused_membound_plane_beats_split_on_the_step_clock() {
        let trace = serve_trace(12, 300.0, 17);
        let mk = |mb_fusion| ServeConfig {
            mb_fusion,
            max_batch: 8,
            ..ServeConfig::default()
        };
        let off = ServeEngine::new(mk(MbFusion::Off))
            .unwrap()
            .run_trace(&trace)
            .unwrap();
        let fused = ServeEngine::new(mk(MbFusion::Fused))
            .unwrap()
            .run_trace(&trace)
            .unwrap();
        let split = ServeEngine::new(mk(MbFusion::Split))
            .unwrap()
            .run_trace(&trace)
            .unwrap();
        // the plane costs time, and fusing it back wins some of it
        assert!(off.membound.is_none());
        assert!(fused.makespan_s > off.makespan_s);
        assert!(
            split.makespan_s > fused.makespan_s,
            "{} !> {}",
            split.makespan_s,
            fused.makespan_s
        );
        let f = fused.membound.as_ref().expect("membound stats");
        let s = split.membound.as_ref().expect("membound stats");
        assert!(f.steps > 0 && s.time_s > f.time_s);
        // the off-path json is byte-identical to the pre-plane engine
        assert!(!off.to_json().dump().contains("membound"));
        assert!(fused.to_json().dump().contains("membound"));
    }

    #[test]
    fn multi_gpu_lanes_balance_and_scale() {
        // near-simultaneous arrivals saturate the node, so aggregate
        // decode throughput must scale with the GPU count
        let trace = serve_trace(64, 100000.0, 13);
        let mk = |n_gpus: u32| ServeConfig {
            n_gpus,
            max_batch: 8,
            num_blocks: 1024,
            ..ServeConfig::default()
        };
        let one = ServeEngine::new(mk(1)).unwrap().run_trace(&trace).unwrap();
        let two = ServeEngine::new(mk(2)).unwrap().run_trace(&trace).unwrap();
        assert_eq!(one.n_gpus, 1);
        assert_eq!(two.n_gpus, 2);
        assert_eq!(two.per_gpu.len(), 2);
        // the load balancer used both lanes, and each stayed bounded
        for lane in &two.per_gpu {
            assert!(lane.admitted > 0 && lane.decode_tokens > 0);
            assert!(lane.peak_occupancy > 0.0 && lane.peak_occupancy <= 1.0);
        }
        // wider node: shorter makespan, higher aggregate throughput
        assert!(
            two.makespan_s < one.makespan_s,
            "{} !< {}",
            two.makespan_s,
            one.makespan_s
        );
        assert!(two.throughput_tok_s > one.throughput_tok_s);
        // and the multi-GPU trace replays bit-identically
        let again = ServeEngine::new(mk(2)).unwrap().run_trace(&trace).unwrap();
        assert_eq!(two.to_json().dump(), again.to_json().dump());
    }

    #[test]
    fn fp8_kv_at_equal_budget_relieves_preemption_pressure() {
        // a per-GPU budget sized to give the bf16 engine a deliberately
        // tiny pool (96 blocks at the default 8x128x16 geometry)
        let budget = 96.0 * 65536.0;
        let mk = |kv_dtype| {
            ServeConfig {
                kv_dtype,
                max_batch: 8,
                shared_prefix_tokens: 32,
                ..ServeConfig::default()
            }
            .with_kv_budget(budget)
        };
        let bf16_cfg = mk(Dtype::Bf16);
        let fp8_cfg = mk(Dtype::Fp8);
        // half the bytes per KV block -> exactly 2x the blocks
        assert_eq!(bf16_cfg.kv_block_bytes(), 65536.0);
        assert_eq!(fp8_cfg.kv_block_bytes(), 32768.0);
        assert_eq!(bf16_cfg.num_blocks, 96);
        assert_eq!(fp8_cfg.num_blocks, 192);

        let trace = serve_trace(24, 500.0, 9);
        let mut b = ServeEngine::new(bf16_cfg).unwrap();
        let mut f = ServeEngine::new(fp8_cfg).unwrap();
        let br = b.run_trace(&trace).unwrap();
        let fr = f.run_trace(&trace).unwrap();
        assert_eq!(br.served, 24);
        assert_eq!(fr.served, 24);
        // double the blocks from the same HBM: the KV plane can only
        // get less contended
        assert!(
            fr.preemptions <= br.preemptions,
            "fp8 {} !<= bf16 {}",
            fr.preemptions,
            br.preemptions
        );
        assert!(fr.kv.failed_admissions <= br.kv.failed_admissions);
        b.kv().validate().unwrap();
        f.kv().validate().unwrap();
        // the default path is unchanged: Bf16 KV is the default dtype
        assert_eq!(ServeConfig::default().kv_dtype, Dtype::Bf16);
    }

    #[test]
    fn impossible_request_errors_out() {
        let cfg = ServeConfig {
            num_blocks: 4,
            shared_prefix_tokens: 0,
            ..ServeConfig::default()
        };
        let mut eng = ServeEngine::new(cfg.clone()).unwrap();
        let trace = vec![ServeRequest {
            id: 0,
            arrival_s: 0.0,
            prompt_tokens: 4096,
            output_tokens: 8,
        }];
        assert!(eng.run_trace(&trace).is_err());

        // the prompt fits but prompt+output can never fit: must be a
        // clean error, not an endless preempt/re-prefill livelock
        let mut eng = ServeEngine::new(ServeConfig {
            num_blocks: 8, // 128 tokens
            ..cfg
        })
        .unwrap();
        let trace = vec![ServeRequest {
            id: 0,
            arrival_s: 0.0,
            prompt_tokens: 64,
            output_tokens: 128,
        }];
        assert!(eng.run_trace(&trace).is_err());
    }
}
