//! Minimal JSON parser + serializer — enough for the artifact manifest
//! and the tuning cache (objects, arrays, strings, numbers, booleans,
//! null). Built in-repo because the environment is offline; the crate
//! carries no external dependencies.

use crate::bail;
use crate::error::Result;
use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }

    /// Convenience constructor: an object from key/value pairs.
    pub fn obj<K: Into<String>>(pairs: Vec<(K, Json)>) -> Json {
        Json::Obj(pairs.into_iter().map(|(k, v)| (k.into(), v)).collect())
    }

    /// Serialize to a compact JSON document.
    pub fn dump(&self) -> String {
        let mut out = String::new();
        self.write_to(&mut out);
        out
    }

    fn write_to(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(n) => out.push_str(&fmt_f64(*n)),
            Json::Str(s) => {
                out.push('"');
                escape_into(s, out);
                out.push('"');
            }
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write_to(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    out.push('"');
                    escape_into(k, out);
                    out.push_str("\":");
                    v.write_to(out);
                }
                out.push('}');
            }
        }
    }
}

fn escape_into(s: &str, out: &mut String) {
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\t' => out.push_str("\\t"),
            '\r' => out.push_str("\\r"),
            c if (c as u32) < 0x20 => {
                out.push_str(&format!("\\u{:04x}", c as u32));
            }
            c => out.push(c),
        }
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        bail!("trailing garbage at byte {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!(
                "expected '{}' at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            )
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other, self.i),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => bail!("expected , or }} found {:?}", other),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                other => bail!("expected , or ] found {:?}", other),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'u') => {
                            if self.i + 5 > self.b.len() {
                                bail!("truncated \\u escape at byte {}", self.i);
                            }
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            s.push(char::from_u32(cp).unwrap_or('?'));
                            self.i += 4;
                        }
                        other => bail!("bad escape {:?}", other),
                    }
                    self.i += 1;
                }
                Some(c) => {
                    // copy a run of plain bytes
                    let start = self.i;
                    while self.i < self.b.len()
                        && self.b[self.i] != b'"'
                        && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                    let _ = c;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }
}

/// Serialize a float in a JSON-safe way (non-finite values clamp to 0:
/// JSON has no NaN/Infinity literals).
pub fn fmt_f64(v: f64) -> String {
    if !v.is_finite() {
        "0".to_string()
    } else if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
          "model": {"vocab": 2048, "d_model": 256},
          "entries": [
            {"name": "gemm256", "file": "gemm256.hlo.txt",
             "inputs": [{"shape": [256, 256], "dtype": "float32"}],
             "outputs": [{"shape": [256, 256], "dtype": "float32"}],
             "meta": {"kind": "gemm", "m": 256}}
          ]
        }"#;
        let j = parse(doc).unwrap();
        assert_eq!(
            j.get("model").unwrap().get("vocab").unwrap().as_u64(),
            Some(2048)
        );
        let e = &j.get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.get("name").unwrap().as_str(), Some("gemm256"));
        let shape = e.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_u64(), Some(256));
    }

    #[test]
    fn parses_scalars_and_escapes() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            parse(r#""a\nb\"cA""#).unwrap(),
            Json::Str("a\nb\"cA".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
        // truncated \u escape must error, not panic (the tune cache is a
        // hand-editable file routed through this parser)
        assert!(parse(r#""\u1"#).is_err());
        assert!(parse(r#""\u12"#).is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }

    #[test]
    fn dump_round_trips() {
        let doc = Json::obj(vec![
            ("s", Json::Str("a\n\"b\"\\c".into())),
            ("n", Json::Num(-1.25)),
            ("i", Json::Num(42.0)),
            ("b", Json::Bool(true)),
            ("z", Json::Null),
            (
                "arr",
                Json::Arr(vec![Json::Num(1.0), Json::Str("x".into())]),
            ),
        ]);
        let text = doc.dump();
        let back = parse(&text).unwrap();
        assert_eq!(back, doc, "{text}");
    }

    #[test]
    fn dump_clamps_non_finite() {
        assert_eq!(Json::Num(f64::NAN).dump(), "0");
        assert_eq!(Json::Num(f64::INFINITY).dump(), "0");
    }
}
