//! Minimal JSON parser — enough for the artifact manifest (objects,
//! arrays, strings, numbers, booleans, null). Built in-repo because the
//! environment is offline; no external crates beyond `xla`/`anyhow`.

use anyhow::{bail, Result};
use std::collections::BTreeMap;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    pub fn get(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_u64(&self) -> Option<u64> {
        self.as_f64().map(|f| f as u64)
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(v) => Some(v),
            _ => None,
        }
    }
}

/// Parse a JSON document.
pub fn parse(text: &str) -> Result<Json> {
    let mut p = Parser { b: text.as_bytes(), i: 0 };
    p.ws();
    let v = p.value()?;
    p.ws();
    if p.i != p.b.len() {
        bail!("trailing garbage at byte {}", p.i);
    }
    Ok(v)
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn ws(&mut self) {
        while self.i < self.b.len()
            && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r')
        {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn expect(&mut self, c: u8) -> Result<()> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            bail!(
                "expected '{}' at byte {}, found {:?}",
                c as char,
                self.i,
                self.peek().map(|b| b as char)
            )
        }
    }

    fn value(&mut self) -> Result<Json> {
        self.ws();
        match self.peek() {
            Some(b'{') => self.object(),
            Some(b'[') => self.array(),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'n') => self.lit("null", Json::Null),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            other => bail!("unexpected {:?} at byte {}", other, self.i),
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            bail!("bad literal at byte {}", self.i)
        }
    }

    fn object(&mut self) -> Result<Json> {
        self.expect(b'{')?;
        let mut m = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(m));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.expect(b':')?;
            let v = self.value()?;
            m.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(m));
                }
                other => bail!("expected , or }} found {:?}", other),
            }
        }
    }

    fn array(&mut self) -> Result<Json> {
        self.expect(b'[')?;
        let mut v = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(v));
        }
        loop {
            v.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => {
                    self.i += 1;
                }
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(v));
                }
                other => bail!("expected , or ] found {:?}", other),
            }
        }
    }

    fn string(&mut self) -> Result<String> {
        self.expect(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => bail!("unterminated string"),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'n') => s.push('\n'),
                        Some(b't') => s.push('\t'),
                        Some(b'r') => s.push('\r'),
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'u') => {
                            let hex = std::str::from_utf8(
                                &self.b[self.i + 1..self.i + 5],
                            )?;
                            let cp = u32::from_str_radix(hex, 16)?;
                            s.push(char::from_u32(cp).unwrap_or('?'));
                            self.i += 4;
                        }
                        other => bail!("bad escape {:?}", other),
                    }
                    self.i += 1;
                }
                Some(c) => {
                    // copy a run of plain bytes
                    let start = self.i;
                    while self.i < self.b.len()
                        && self.b[self.i] != b'"'
                        && self.b[self.i] != b'\\'
                    {
                        self.i += 1;
                    }
                    s.push_str(std::str::from_utf8(&self.b[start..self.i])?);
                    let _ = c;
                }
            }
        }
    }

    fn number(&mut self) -> Result<Json> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .is_some_and(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i])?;
        Ok(Json::Num(s.parse::<f64>()?))
    }
}

/// Serialize a float in a JSON-safe way.
pub fn fmt_f64(v: f64) -> String {
    if v.fract() == 0.0 && v.abs() < 1e15 {
        format!("{}", v as i64)
    } else {
        format!("{v}")
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_like_document() {
        let doc = r#"{
          "model": {"vocab": 2048, "d_model": 256},
          "entries": [
            {"name": "gemm256", "file": "gemm256.hlo.txt",
             "inputs": [{"shape": [256, 256], "dtype": "float32"}],
             "outputs": [{"shape": [256, 256], "dtype": "float32"}],
             "meta": {"kind": "gemm", "m": 256}}
          ]
        }"#;
        let j = parse(doc).unwrap();
        assert_eq!(
            j.get("model").unwrap().get("vocab").unwrap().as_u64(),
            Some(2048)
        );
        let e = &j.get("entries").unwrap().as_arr().unwrap()[0];
        assert_eq!(e.get("name").unwrap().as_str(), Some("gemm256"));
        let shape = e.get("inputs").unwrap().as_arr().unwrap()[0]
            .get("shape")
            .unwrap()
            .as_arr()
            .unwrap();
        assert_eq!(shape[0].as_u64(), Some(256));
    }

    #[test]
    fn parses_scalars_and_escapes() {
        assert_eq!(parse("null").unwrap(), Json::Null);
        assert_eq!(parse("true").unwrap(), Json::Bool(true));
        assert_eq!(parse("-1.5e2").unwrap(), Json::Num(-150.0));
        assert_eq!(
            parse(r#""a\nb\"cA""#).unwrap(),
            Json::Str("a\nb\"cA".into())
        );
    }

    #[test]
    fn rejects_garbage() {
        assert!(parse("{").is_err());
        assert!(parse("[1,]").is_err());
        assert!(parse("12 34").is_err());
        assert!(parse(r#"{"a" 1}"#).is_err());
    }

    #[test]
    fn empty_containers() {
        assert_eq!(parse("[]").unwrap(), Json::Arr(vec![]));
        assert_eq!(parse("{}").unwrap(), Json::Obj(BTreeMap::new()));
    }
}
