//! Typed view of `artifacts/manifest.json` (written by `compile/aot.py`).

use super::json::{parse, Json};
use crate::err;
use crate::error::{Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// Tensor dtype at the runtime boundary (artifacts keep the boundary
/// simple: f32 data, i32 tokens).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ArtDtype {
    F32,
    I32,
}

impl ArtDtype {
    fn from_str(s: &str) -> Result<Self> {
        match s {
            "float32" => Ok(ArtDtype::F32),
            "int32" => Ok(ArtDtype::I32),
            other => Err(err!("unsupported artifact dtype {other}")),
        }
    }
}

/// Shape + dtype of one input/output.
#[derive(Debug, Clone)]
pub struct TensorSpec {
    pub shape: Vec<usize>,
    pub dtype: ArtDtype,
}

impl TensorSpec {
    pub fn elems(&self) -> usize {
        self.shape.iter().product::<usize>().max(1)
    }

    fn from_json(j: &Json) -> Result<Self> {
        let shape = j
            .get("shape")
            .and_then(|s| s.as_arr())
            .ok_or_else(|| err!("missing shape"))?
            .iter()
            .map(|d| d.as_u64().unwrap_or(0) as usize)
            .collect();
        let dtype = ArtDtype::from_str(
            j.get("dtype").and_then(|d| d.as_str()).unwrap_or("float32"),
        )?;
        Ok(TensorSpec { shape, dtype })
    }
}

/// One artifact entry.
#[derive(Debug, Clone)]
pub struct Entry {
    pub name: String,
    pub file: PathBuf,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub meta: BTreeMap<String, String>,
}

impl Entry {
    pub fn meta_u64(&self, key: &str) -> Option<u64> {
        self.meta.get(key).and_then(|v| v.parse::<f64>().ok()).map(|f| f as u64)
    }
}

/// The whole manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub dir: PathBuf,
    pub entries: Vec<Entry>,
}

impl Manifest {
    /// Load `<dir>/manifest.json`.
    pub fn load(dir: impl AsRef<Path>) -> Result<Self> {
        let dir = dir.as_ref().to_path_buf();
        let path = dir.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {}", path.display()))?;
        let j = parse(&text)?;
        let mut entries = Vec::new();
        for e in j
            .get("entries")
            .and_then(|e| e.as_arr())
            .ok_or_else(|| err!("manifest missing entries"))?
        {
            let name = e
                .get("name")
                .and_then(|n| n.as_str())
                .ok_or_else(|| err!("entry missing name"))?
                .to_string();
            let file = dir.join(
                e.get("file").and_then(|f| f.as_str()).unwrap_or_default(),
            );
            let spec_list = |key: &str| -> Result<Vec<TensorSpec>> {
                e.get(key)
                    .and_then(|l| l.as_arr())
                    .unwrap_or(&[])
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect()
            };
            let mut meta = BTreeMap::new();
            if let Some(Json::Obj(m)) = e.get("meta") {
                for (k, v) in m {
                    let s = match v {
                        Json::Str(s) => s.clone(),
                        Json::Num(n) => super::json::fmt_f64(*n),
                        Json::Bool(b) => b.to_string(),
                        other => format!("{other:?}"),
                    };
                    meta.insert(k.clone(), s);
                }
            }
            entries.push(Entry {
                name,
                file,
                inputs: spec_list("inputs")?,
                outputs: spec_list("outputs")?,
                meta,
            });
        }
        Ok(Manifest { dir, entries })
    }

    pub fn entry(&self, name: &str) -> Result<&Entry> {
        self.entries
            .iter()
            .find(|e| e.name == name)
            .ok_or_else(|| err!("no artifact entry named {name}"))
    }

    /// The directory exists and has a manifest (used by tests to skip
    /// gracefully when `make artifacts` hasn't run).
    pub fn available(dir: impl AsRef<Path>) -> bool {
        dir.as_ref().join("manifest.json").exists()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_manifest_from_temp_dir() {
        let dir = std::env::temp_dir().join("hk_manifest_test");
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"entries": [{"name": "x", "file": "x.hlo.txt",
                "inputs": [{"shape": [2, 3], "dtype": "float32"}],
                "outputs": [{"shape": [6], "dtype": "int32"}],
                "meta": {"kind": "test", "n_params": 42}}]}"#,
        )
        .unwrap();
        let m = Manifest::load(&dir).unwrap();
        let e = m.entry("x").unwrap();
        assert_eq!(e.inputs[0].shape, vec![2, 3]);
        assert_eq!(e.inputs[0].elems(), 6);
        assert_eq!(e.outputs[0].dtype, ArtDtype::I32);
        assert_eq!(e.meta_u64("n_params"), Some(42));
        assert!(m.entry("y").is_err());
        assert!(Manifest::available(&dir));
    }
}
