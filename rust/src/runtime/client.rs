//! Execution-backend seam: load HLO-text artifacts and execute them from
//! the Rust hot path.
//!
//! The real backend is a PJRT CPU client (`PjRtClient::cpu()` ->
//! `HloModuleProto::from_text_file` -> compile -> execute). That client
//! lives behind the `pjrt` cargo feature and a vendored `xla` crate —
//! neither of which exists in this offline environment — so the default
//! build ships a *stub* backend: it loads the manifest, type-checks
//! tensors against entry specs, and reports a clear error on execution.
//! Everything above this seam (`coordinator`, benches, tests) is
//! backend-agnostic; artifact-dependent tests skip when `make artifacts`
//! has not produced a manifest.

use super::manifest::{ArtDtype, Entry, Manifest, TensorSpec};
use crate::bail;
use crate::error::Result;
use std::collections::HashMap;

// The feature seam is honest: enabling `pjrt` without vendoring the
// `xla` crate and swapping in the real client must fail loudly at
// compile time, not silently rebuild the stub.
#[cfg(feature = "pjrt")]
compile_error!(
    "the `pjrt` backend needs a vendored `xla` crate wired into \
     runtime::client; see README \"Execution plane\""
);

/// Input tensor at the runtime boundary.
#[derive(Debug, Clone)]
pub enum Tensor {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Tensor {
    pub fn len(&self) -> usize {
        match self {
            Tensor::F32(v) => v.len(),
            Tensor::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32(v) => Ok(v),
            _ => bail!("expected f32 tensor"),
        }
    }

    /// Validate this tensor against an entry spec (shape volume + dtype).
    fn check(&self, spec: &TensorSpec) -> Result<()> {
        if self.len() != spec.elems() {
            bail!(
                "tensor has {} elems, spec wants {:?} = {}",
                self.len(),
                spec.shape,
                spec.elems()
            );
        }
        let matches = matches!(
            (self, spec.dtype),
            (Tensor::F32(_), ArtDtype::F32) | (Tensor::I32(_), ArtDtype::I32)
        );
        if !matches {
            bail!("tensor dtype does not match spec {:?}", spec.dtype);
        }
        Ok(())
    }
}

/// One loaded artifact.
pub struct Executable {
    pub entry: Entry,
    /// Cumulative execution stats. Only a real backend advances these;
    /// the stub's `run` fails before recording, so they stay zero.
    pub calls: std::cell::Cell<u64>,
    pub total_s: std::cell::Cell<f64>,
}

impl Executable {
    /// Execute with boundary tensors; returns one Tensor per output.
    ///
    /// The stub backend validates arity, shapes and dtypes — so callers
    /// get the same early errors the PJRT path produced — then fails with
    /// a backend-unavailable error.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        if inputs.len() != self.entry.inputs.len() {
            bail!(
                "{} takes {} inputs, got {}",
                self.entry.name,
                self.entry.inputs.len(),
                inputs.len()
            );
        }
        for (t, s) in inputs.iter().zip(&self.entry.inputs) {
            t.check(s)?;
        }
        bail!(
            "artifact {} loaded but no execution backend is available: the \
             PJRT client requires the `pjrt` feature and a vendored `xla` \
             crate (see README, \"Execution plane\")",
            self.entry.name
        )
    }

    /// Mean latency over all calls so far, seconds.
    pub fn mean_latency_s(&self) -> f64 {
        if self.calls.get() == 0 {
            0.0
        } else {
            self.total_s.get() / self.calls.get() as f64
        }
    }
}

/// The runtime: an artifact manifest plus loaded executables.
pub struct Runtime {
    pub manifest: Manifest,
    compiled: HashMap<String, Executable>,
}

impl Runtime {
    /// Create against an artifacts directory (loads lazily).
    pub fn new(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = Manifest::load(&artifacts_dir)?;
        Ok(Runtime { manifest, compiled: HashMap::new() })
    }

    /// Backend identification string.
    pub fn platform(&self) -> String {
        "native-stub (build with --features pjrt for the PJRT CPU client)"
            .to_string()
    }

    /// Load (or fetch) an entry by name.
    pub fn load(&mut self, name: &str) -> Result<&Executable> {
        if !self.compiled.contains_key(name) {
            let entry = self.manifest.entry(name)?.clone();
            self.compiled.insert(
                name.to_string(),
                Executable {
                    entry,
                    calls: std::cell::Cell::new(0),
                    total_s: std::cell::Cell::new(0.0),
                },
            );
        }
        Ok(&self.compiled[name])
    }

    /// Convenience: load + run.
    pub fn run(&mut self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.load(name)?;
        self.compiled[name].run(inputs)
    }

    /// Names of all manifest entries.
    pub fn entry_names(&self) -> Vec<String> {
        self.manifest.entries.iter().map(|e| e.name.clone()).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn manifest_dir(name: &str) -> std::path::PathBuf {
        // one dir per test: cargo runs tests in parallel and the write
        // below must not race another test's Manifest::load
        let dir = std::env::temp_dir().join(format!("hk_client_stub_{name}"));
        std::fs::create_dir_all(&dir).unwrap();
        std::fs::write(
            dir.join("manifest.json"),
            r#"{"entries": [{"name": "gemm2", "file": "gemm2.hlo.txt",
                "inputs": [{"shape": [2, 2], "dtype": "float32"},
                           {"shape": [2, 2], "dtype": "float32"}],
                "outputs": [{"shape": [2, 2], "dtype": "float32"}],
                "meta": {"kind": "gemm"}}]}"#,
        )
        .unwrap();
        dir
    }

    #[test]
    fn stub_validates_before_failing() {
        let mut rt = Runtime::new(manifest_dir("validate")).unwrap();
        // wrong arity
        let e = rt.run("gemm2", &[]).unwrap_err();
        assert!(e.to_string().contains("takes 2 inputs"), "{e}");
        // wrong shape
        let bad = vec![Tensor::F32(vec![0.0; 3]), Tensor::F32(vec![0.0; 4])];
        let e = rt.run("gemm2", &bad).unwrap_err();
        assert!(e.to_string().contains("3 elems"), "{e}");
        // wrong dtype
        let bad = vec![Tensor::I32(vec![0; 4]), Tensor::F32(vec![0.0; 4])];
        let e = rt.run("gemm2", &bad).unwrap_err();
        assert!(e.to_string().contains("dtype"), "{e}");
        // well-formed input reaches the backend seam
        let ok = vec![Tensor::F32(vec![0.0; 4]), Tensor::F32(vec![0.0; 4])];
        let e = rt.run("gemm2", &ok).unwrap_err();
        assert!(e.to_string().contains("no execution backend"), "{e}");
    }

    #[test]
    fn load_tracks_entries() {
        let mut rt = Runtime::new(manifest_dir("load")).unwrap();
        assert_eq!(rt.entry_names(), vec!["gemm2".to_string()]);
        let exe = rt.load("gemm2").unwrap();
        assert_eq!(exe.calls.get(), 0);
        assert_eq!(exe.mean_latency_s(), 0.0);
        assert!(rt.load("nope").is_err());
        assert!(!rt.platform().is_empty());
    }
}
