//! PJRT client wrapper: load HLO-text artifacts, compile once, execute
//! many times from the Rust hot path.
//!
//! Pattern follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` ->
//! `HloModuleProto::from_text_file` -> `XlaComputation::from_proto` ->
//! `client.compile` -> `execute`. All entries are lowered with
//! `return_tuple=True`, so outputs always arrive as one tuple literal.

use super::manifest::{ArtDtype, Entry, Manifest, TensorSpec};
use anyhow::{anyhow, bail, Context, Result};
use std::collections::HashMap;
use std::time::Instant;

/// Input tensor at the runtime boundary.
#[derive(Debug, Clone)]
pub enum Tensor {
    F32(Vec<f32>),
    I32(Vec<i32>),
}

impl Tensor {
    pub fn len(&self) -> usize {
        match self {
            Tensor::F32(v) => v.len(),
            Tensor::I32(v) => v.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            Tensor::F32(v) => Ok(v),
            _ => bail!("expected f32 tensor"),
        }
    }

    fn literal(&self, spec: &TensorSpec) -> Result<xla::Literal> {
        if self.len() != spec.elems() {
            bail!(
                "tensor has {} elems, spec wants {:?} = {}",
                self.len(),
                spec.shape,
                spec.elems()
            );
        }
        let dims: Vec<i64> = spec.shape.iter().map(|&d| d as i64).collect();
        let lit = match (self, spec.dtype) {
            (Tensor::F32(v), ArtDtype::F32) => xla::Literal::vec1(v),
            (Tensor::I32(v), ArtDtype::I32) => xla::Literal::vec1(v),
            _ => bail!("tensor dtype does not match spec {:?}", spec.dtype),
        };
        if dims.is_empty() || dims.len() == 1 && dims[0] as usize == self.len() {
            if dims.is_empty() {
                return Ok(lit.reshape(&[])?);
            }
            return Ok(lit);
        }
        Ok(lit.reshape(&dims)?)
    }
}

/// One compiled artifact.
pub struct Executable {
    pub entry: Entry,
    exe: xla::PjRtLoadedExecutable,
    /// Cumulative execution stats.
    pub calls: std::cell::Cell<u64>,
    pub total_s: std::cell::Cell<f64>,
}

impl Executable {
    /// Execute with boundary tensors; returns one Tensor per output.
    pub fn run(&self, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        if inputs.len() != self.entry.inputs.len() {
            bail!(
                "{} takes {} inputs, got {}",
                self.entry.name,
                self.entry.inputs.len(),
                inputs.len()
            );
        }
        let lits: Vec<xla::Literal> = inputs
            .iter()
            .zip(&self.entry.inputs)
            .map(|(t, s)| t.literal(s))
            .collect::<Result<_>>()?;
        let t0 = Instant::now();
        let result = self.exe.execute::<xla::Literal>(&lits)?[0][0]
            .to_literal_sync()?;
        let dt = t0.elapsed().as_secs_f64();
        self.calls.set(self.calls.get() + 1);
        self.total_s.set(self.total_s.get() + dt);
        let parts = result.to_tuple()?;
        parts
            .into_iter()
            .zip(&self.entry.outputs)
            .map(|(lit, spec)| {
                Ok(match spec.dtype {
                    ArtDtype::F32 => Tensor::F32(lit.to_vec::<f32>()?),
                    ArtDtype::I32 => Tensor::I32(lit.to_vec::<i32>()?),
                })
            })
            .collect()
    }

    /// Mean latency over all calls so far, seconds.
    pub fn mean_latency_s(&self) -> f64 {
        if self.calls.get() == 0 {
            0.0
        } else {
            self.total_s.get() / self.calls.get() as f64
        }
    }
}

/// The runtime: a PJRT CPU client plus compiled artifacts.
pub struct Runtime {
    pub manifest: Manifest,
    client: xla::PjRtClient,
    compiled: HashMap<String, Executable>,
}

impl Runtime {
    /// Create against an artifacts directory (compiles lazily).
    pub fn new(artifacts_dir: impl AsRef<std::path::Path>) -> Result<Self> {
        let manifest = Manifest::load(&artifacts_dir)?;
        let client = xla::PjRtClient::cpu()
            .map_err(|e| anyhow!("PJRT CPU client: {e:?}"))?;
        Ok(Runtime { manifest, client, compiled: HashMap::new() })
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Compile (or fetch) an entry by name.
    pub fn load(&mut self, name: &str) -> Result<&Executable> {
        if !self.compiled.contains_key(name) {
            let entry = self.manifest.entry(name)?.clone();
            let proto = xla::HloModuleProto::from_text_file(&entry.file)
                .map_err(|e| {
                    anyhow!("parsing {}: {e:?}", entry.file.display())
                })?;
            let comp = xla::XlaComputation::from_proto(&proto);
            let exe = self
                .client
                .compile(&comp)
                .map_err(|e| anyhow!("compiling {name}: {e:?}"))
                .with_context(|| format!("artifact {name}"))?;
            self.compiled.insert(
                name.to_string(),
                Executable {
                    entry,
                    exe,
                    calls: std::cell::Cell::new(0),
                    total_s: std::cell::Cell::new(0.0),
                },
            );
        }
        Ok(&self.compiled[name])
    }

    /// Convenience: load + run.
    pub fn run(&mut self, name: &str, inputs: &[Tensor]) -> Result<Vec<Tensor>> {
        self.load(name)?;
        self.compiled[name].run(inputs)
    }

    /// Names of all manifest entries.
    pub fn entry_names(&self) -> Vec<String> {
        self.manifest.entries.iter().map(|e| e.name.clone()).collect()
    }
}
