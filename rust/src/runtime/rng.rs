//! Small deterministic PRNG (xoshiro256**) + helpers for synthetic
//! workloads. Built in-repo (offline environment, no `rand` crate).

/// xoshiro256** — fast, high-quality, seedable.
#[derive(Debug, Clone)]
pub struct Rng {
    s: [u64; 4],
}

impl Rng {
    pub fn new(seed: u64) -> Self {
        // splitmix64 expansion of the seed
        let mut x = seed.wrapping_add(0x9E3779B97F4A7C15);
        let mut next = || {
            x = x.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = x;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        Rng { s: [next(), next(), next(), next()] }
    }

    pub fn next_u64(&mut self) -> u64 {
        let r = self.s[1]
            .wrapping_mul(5)
            .rotate_left(7)
            .wrapping_mul(9);
        let t = self.s[1] << 17;
        self.s[2] ^= self.s[0];
        self.s[3] ^= self.s[1];
        self.s[1] ^= self.s[2];
        self.s[0] ^= self.s[3];
        self.s[2] ^= t;
        self.s[3] = self.s[3].rotate_left(45);
        r
    }

    /// Uniform in [0, 1).
    pub fn f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 / (1u64 << 53) as f64
    }

    /// Uniform f32 in [0, 1).
    pub fn f32(&mut self) -> f32 {
        self.f64() as f32
    }

    /// Standard normal via Box-Muller.
    pub fn normal(&mut self) -> f32 {
        let u1 = self.f64().max(1e-12);
        let u2 = self.f64();
        ((-2.0 * u1.ln()).sqrt() * (std::f64::consts::TAU * u2).cos()) as f32
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: u64) -> u64 {
        if n == 0 {
            return 0;
        }
        self.next_u64() % n
    }

    /// A vector of standard-normal f32s (the benchmark protocol's
    /// N(0,1) inputs).
    pub fn normal_vec(&mut self, n: usize) -> Vec<f32> {
        (0..n).map(|_| self.normal()).collect()
    }

    /// Exponential inter-arrival sample with rate lambda (per second).
    pub fn exp(&mut self, lambda: f64) -> f64 {
        -self.f64().max(1e-12).ln() / lambda
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        let mut c = Rng::new(8);
        let va: Vec<u64> = (0..8).map(|_| a.next_u64()).collect();
        let vb: Vec<u64> = (0..8).map(|_| b.next_u64()).collect();
        let vc: Vec<u64> = (0..8).map(|_| c.next_u64()).collect();
        assert_eq!(va, vb);
        assert_ne!(va, vc);
    }

    #[test]
    fn normal_has_sane_moments() {
        let mut r = Rng::new(42);
        let v = r.normal_vec(20_000);
        let mean: f32 = v.iter().sum::<f32>() / v.len() as f32;
        let var: f32 =
            v.iter().map(|x| (x - mean) * (x - mean)).sum::<f32>() / v.len() as f32;
        assert!(mean.abs() < 0.03, "{mean}");
        assert!((var - 1.0).abs() < 0.05, "{var}");
    }

    #[test]
    fn below_is_in_range() {
        let mut r = Rng::new(1);
        for _ in 0..1000 {
            assert!(r.below(17) < 17);
        }
        assert_eq!(r.below(0), 0);
    }

    #[test]
    fn uniform_covers_unit_interval() {
        let mut r = Rng::new(3);
        let (mut lo, mut hi) = (1.0f64, 0.0f64);
        for _ in 0..10_000 {
            let x = r.f64();
            lo = lo.min(x);
            hi = hi.max(x);
            assert!((0.0..1.0).contains(&x));
        }
        assert!(lo < 0.01 && hi > 0.99);
    }
}
