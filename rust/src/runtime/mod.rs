//! Artifact runtime: load the AOT-compiled HLO artifacts and execute
//! them from Rust. Python never runs on this path — `make artifacts` is
//! the only compile-time step.
//!
//! - [`json`] — a minimal JSON parser/serializer for the artifact
//!   manifest and the tuning cache (the environment is offline; we build
//!   the substrate ourselves).
//! - [`manifest`] — typed view of `artifacts/manifest.json`.
//! - [`client`] — the execution-backend seam. The PJRT CPU client sits
//!   behind the `pjrt` feature (vendored `xla` crate); the default build
//!   ships a validating stub so the crate is dependency-free.
//! - [`rng`] — a small deterministic PRNG (xoshiro-style) for synthetic
//!   workloads on the request path.
//! - [`par`] — scoped-thread parallel map for the bench harness;
//!   results merge in input order so artifacts stay byte-identical.

pub mod client;
pub mod json;
pub mod manifest;
pub mod par;
pub mod rng;

pub use client::{Executable, Runtime, Tensor};
pub use manifest::{Entry, Manifest, TensorSpec};
pub use par::par_map;
pub use rng::Rng;
