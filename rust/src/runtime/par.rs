//! Zero-dependency parallel map over owned work items.
//!
//! The report harness is a pile of independent, CPU-bound cost-model
//! evaluations — serve-trace A/B/C runs, the calibration grid, sweep
//! cells. [`par_map`] fans them across a scoped `std::thread` pool and
//! merges results **in input index order**, so anything serialized from
//! the merged vector (every `BENCH_*.json`) is byte-identical to the
//! serial evaluation — parallelism changes wall-clock time only, never
//! artifact bytes.
//!
//! Implementation notes:
//! - `std::thread::scope` keeps the closure borrow-checked against the
//!   caller's stack (no `'static` bounds, no `Arc`).
//! - Work is pulled from a shared `Mutex<VecDeque>` so a slow item
//!   (one serve run) does not idle the workers holding fast items.
//! - A worker panic propagates out of the scope, exactly like the
//!   serial loop would.

use std::collections::VecDeque;
use std::sync::Mutex;

/// Map `f` over `items` on up to `available_parallelism` worker
/// threads, returning results in input order. Falls back to the plain
/// serial map for 0 or 1 items (no thread overhead on the trivial
/// case).
pub fn par_map<T, R>(items: Vec<T>, f: impl Fn(T) -> R + Sync) -> Vec<R>
where
    T: Send,
    R: Send,
{
    let n = items.len();
    if n <= 1 {
        return items.into_iter().map(f).collect();
    }
    let workers = std::thread::available_parallelism()
        .map(|p| p.get())
        .unwrap_or(4)
        .min(n);
    let work: Mutex<VecDeque<(usize, T)>> =
        Mutex::new(items.into_iter().enumerate().collect());
    let slots: Mutex<Vec<Option<R>>> =
        Mutex::new((0..n).map(|_| None).collect());
    std::thread::scope(|s| {
        for _ in 0..workers {
            s.spawn(|| loop {
                // lock only to pull the next item; f runs unlocked
                let job = work.lock().unwrap_or_else(|e| e.into_inner()).pop_front();
                match job {
                    Some((i, item)) => {
                        let r = f(item);
                        slots.lock().unwrap_or_else(|e| e.into_inner())[i] =
                            Some(r);
                    }
                    None => break,
                }
            });
        }
    });
    slots
        .into_inner()
        .unwrap_or_else(|e| e.into_inner())
        .into_iter()
        .map(|r| r.expect("par_map worker dropped an item"))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn results_come_back_in_input_order() {
        // uneven per-item work so completion order differs from input
        // order; the merge must still be by index
        let items: Vec<u64> = (0..64).collect();
        let expect: Vec<u64> = items.iter().map(|&x| x * x).collect();
        let got = par_map(items, |x| {
            let spin = (64 - x) * 1000;
            let mut acc = 0u64;
            for i in 0..spin {
                acc = acc.wrapping_add(i);
            }
            std::hint::black_box(acc);
            x * x
        });
        assert_eq!(got, expect);
    }

    #[test]
    fn matches_the_serial_map_exactly() {
        let items: Vec<i64> = (-100..100).collect();
        let serial: Vec<i64> = items.iter().map(|&x| x * 3 - 7).collect();
        assert_eq!(par_map(items, |x| x * 3 - 7), serial);
    }

    #[test]
    fn trivial_sizes_take_the_serial_path() {
        assert_eq!(par_map(Vec::<u32>::new(), |x| x + 1), Vec::<u32>::new());
        assert_eq!(par_map(vec![41u32], |x| x + 1), vec![42]);
    }
}
