//! # HipKittens (reproduction)
//!
//! A three-layer reproduction of *"HipKittens: Fast and Furious AMD
//! Kernels"* (Hu et al., 2025):
//!
//! - [`sim`] — a cycle-approximate CDNA3/CDNA4 GPU simulator (the
//!   hardware substrate the paper's evaluation requires; see DESIGN.md
//!   for the substitution rationale).
//! - [`hk`] — the HipKittens programming framework: tiles, layouts,
//!   swizzles, register pinning, the 8-wave ping-pong / 4-wave interleave
//!   / wave-specialization scheduling patterns, and the chiplet-aware
//!   grid swizzle (Algorithm 1).
//! - [`kernels`] — the paper's kernel suite (GEMM BF16/FP8/FP6,
//!   attention forward/backward, fused layernorm, RoPE) plus behavioural
//!   baseline models (AITER, CK, hipBLASLt, Triton, PyTorch), unified
//!   behind the autotuned dispatch registry (`kernels::registry`).
//! - [`runtime`] — execution of the AOT-compiled JAX/Pallas artifacts
//!   (the numeric plane; python never runs at request time). The PJRT
//!   client sits behind the `pjrt` feature seam.
//! - [`coordinator`] — the serving/training drivers built on the
//!   runtime and the registry (including the mixed-op service).
//! - [`serve`] — the decode-serving subsystem: paged KV cache with
//!   ref-counted prefix sharing + the continuous-batching engine over
//!   `Op::AttnDecode`.
//! - [`moe`] — the Mixture-of-Experts subsystem: top-k routing and
//!   token alignment into the expert-contiguous ragged batches the
//!   `Op::MoeGemm` grouped-GEMM kernel class consumes.
//! - [`report`] — regenerates every table and figure of the paper.

pub mod coordinator;
pub mod error;
pub mod hk;
pub mod kernels;
pub mod moe;
pub mod obs;
pub mod report;
pub mod runtime;
pub mod serve;
pub mod sim;
