//! Quickstart: the three-layer stack in one file.
//!
//! 1. Simulation plane: dispatch one HK BF16 GEMM through the autotuned
//!    kernel registry, run it on the simulated MI355X and print the
//!    paper-style metrics.
//! 2. Execution plane: load the AOT-compiled Pallas GEMM artifact
//!    (`make artifacts`) and execute it on the runtime backend from
//!    Rust, checking the numerics against a host matmul. (The default
//!    build ships the stub backend; see README "Execution plane".)
//!
//! Run: `cargo run --release --example quickstart`

use hipkittens::error::Result;
use hipkittens::kernels::registry::{ArchId, Query};
use hipkittens::runtime::{Rng, Runtime, Tensor};
use hipkittens::sim::Dtype;

fn main() -> Result<()> {
    // --- 1. the simulation plane -------------------------------------
    let arch = ArchId::Mi355x;
    let d = Query::gemm(arch, Dtype::Bf16, 8192, 8192, 8192).dispatch();
    let perf = d.simulate();
    println!(
        "[sim] HK BF16 GEMM 8192^3 on {} (registry variant {}):",
        arch.arch().name,
        d.variant
    );
    println!(
        "[sim]   {:.0} TFLOPS (MFMA util {:.2}, L2 {:.0}%, LLC {:.0}%, {:.1} TB/s)",
        perf.tflops,
        perf.mfma_util,
        perf.l2_hit * 100.0,
        perf.llc_hit * 100.0,
        perf.eff_bw_tbps
    );

    // --- 2. the execution plane --------------------------------------
    let dir = std::env::var("HK_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !hipkittens::runtime::Manifest::available(&dir) {
        println!("[run] artifacts/ missing — run `make artifacts` first");
        return Ok(());
    }
    let mut rt = Runtime::new(&dir)?;
    println!("[run] backend: {}", rt.platform());
    let mut rng = Rng::new(0);
    let n = 256usize;
    let a = rng.normal_vec(n * n);
    let b = rng.normal_vec(n * n);
    let out = rt.run("gemm256", &[Tensor::F32(a.clone()), Tensor::F32(b.clone())])?;
    let got = out[0].as_f32()?;

    // host-side check of one output element
    let (i, j) = (3usize, 7usize);
    let want: f32 = (0..n).map(|k| a[i * n + k] * b[k * n + j]).sum();
    let err = (got[i * n + j] - want).abs();
    println!(
        "[run] gemm256 out[{i},{j}] = {:.4} (host {:.4}, |err| {:.2e})",
        got[i * n + j],
        want,
        err
    );
    assert!(err < 1e-2, "numerics mismatch");
    println!("[run] quickstart OK — Pallas kernel, AOT HLO, Rust execution agree");
    Ok(())
}
