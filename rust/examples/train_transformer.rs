//! End-to-end training driver (DESIGN.md E2E): train the Llama-style
//! transformer through the AOT `train_step` artifact — Pallas flash
//! attention forward AND backward inside — with parameters held in Rust.
//! Logs the loss curve and cross-checks the kernel path against the
//! dense-attention reference path (the paper's §4 stability experiment).
//!
//! Run: `make artifacts && cargo run --release --example train_transformer
//!       [-- --steps 200]`

use hipkittens::coordinator::{predicted_step_s, Path, Trainer};
use hipkittens::error::Result;
use hipkittens::kernels::registry::ArchId;
use hipkittens::runtime::Runtime;

fn main() -> Result<()> {
    let args: Vec<String> = std::env::args().collect();
    let steps: u32 = args
        .iter()
        .position(|a| a == "--steps")
        .and_then(|i| args.get(i + 1))
        .and_then(|v| v.parse().ok())
        .unwrap_or(200);

    let dir = std::env::var("HK_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    let mut rt = Runtime::new(&dir)?;
    println!("backend: {}", rt.platform());

    let mut tr = Trainer::new(&mut rt, 0)?;
    println!(
        "model: {} params, vocab {}, seq {}, batch {}",
        tr.flat.len(),
        tr.vocab,
        tr.seq_len,
        tr.batch
    );

    // registry-dispatched kernel plan for one step on simulated MI355X
    let plan = tr.plan(ArchId::Mi355x);
    println!(
        "kernel plan: {} dispatches, predicted {:.3} ms/step",
        plan.len(),
        predicted_step_s(&plan) * 1e3
    );

    // parity probe: evaluated on the kernel path here, stepped on the
    // reference path below with identical params
    let probe = tr.synthetic_batch();
    let l_k = tr.eval_loss(probe.clone())?;
    println!("initial loss (kernel path): {l_k:.4}");

    let t0 = std::time::Instant::now();
    let losses = tr.train(Path::Kernels, steps, |s, l| {
        if s % 10 == 0 {
            println!("step {s:>4}  loss {l:.4}");
        }
    })?;
    let dt = t0.elapsed().as_secs_f64();
    let first = losses.first().copied().unwrap_or(f32::NAN);
    let last = losses.last().copied().unwrap_or(f32::NAN);
    println!(
        "\ntrained {steps} steps in {dt:.1}s ({:.0} ms/step, {:.0} tok/s)",
        dt / steps as f64 * 1e3,
        steps as f64 * tr.batch as f64 * tr.seq_len as f64 / dt
    );
    println!("loss: {first:.4} -> {last:.4}");
    assert!(last < first, "loss must decrease");

    // reference-path comparison: same init (seed 0), same probe batch
    let mut rt2 = Runtime::new(&dir)?;
    let mut tr_ref = Trainer::new(&mut rt2, 0)?;
    let ref_loss = tr_ref.step(Path::Reference, probe)?;
    println!(
        "parity on identical params+batch: kernel {l_k:.4} vs reference {ref_loss:.4} ({})",
        if (ref_loss - l_k).abs() < 5e-3 { "OK" } else { "DIVERGED" }
    );
    assert!((ref_loss - l_k).abs() < 5e-3, "kernel/reference divergence");
    Ok(())
}
