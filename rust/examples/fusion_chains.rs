//! Fusion-algebra walkthrough: build a memory-bound kernel as a chain
//! of stages, plan it against the register/LDS budget, and price the
//! fused plan against the per-stage split baseline.
//!
//! Covers the three behaviours the algebra guarantees:
//!   1. a legal chain fuses to ONE global-memory pass and beats every
//!      split of itself (intermediates never round-trip through HBM);
//!   2. an over-budget chain is force-split at the cheapest legal cuts
//!      instead of reporting impossible register residency;
//!   3. the registry dispatches the same chains as `Op::FusedChain`,
//!      with `Query::unfused()` as the split-baseline override.
//!
//! Run: `cargo run --release --example fusion_chains`

use hipkittens::hk::regalloc;
use hipkittens::kernels::fusion::{FusionChain, StageKind};
use hipkittens::kernels::registry::{ArchId, Query};

fn main() {
    let arch = ArchId::Mi355x;
    let a = arch.arch();

    println!("== 1. Add+RMSNorm as a chain (rows 65536, d 2048) ==");
    let chain = FusionChain::add_rmsnorm(16 * 4096, 2048);
    let ev = chain.evaluate(&a);
    println!(
        "fused plan: {} pass(es), {:.1} us, {:.2} TB/s effective",
        ev.plan.passes.len(),
        ev.perf.time_s * 1e6,
        ev.perf.eff_bw_tbps
    );
    for p in &ev.per_pass {
        println!("  pass {:<28} {:>8.1} us", p.name, p.time_s * 1e6);
    }
    let split = chain.clone().split_all().evaluate(&a);
    println!(
        "split baseline: {} passes, {:.1} us -> fusion wins {:.2}x",
        split.plan.passes.len(),
        split.perf.time_s * 1e6,
        split.perf.time_s / ev.perf.time_s
    );
    for p in &split.per_pass {
        println!("  pass {:<28} {:>8.1} us", p.name, p.time_s * 1e6);
    }

    println!("\n== 2. an over-budget chain is force-split ==");
    // five stages over d=8192 rows: the fused live set (x, a, b, c)
    // wants more registers than one wave owns, so the planner must cut
    let wide = FusionChain::new("wide-tree", 16 * 1024, 8192)
        .stage(StageKind::Elementwise { passes: 1 }, &["x"], &["a"])
        .stage(StageKind::Elementwise { passes: 1 }, &["x"], &["b"])
        .stage(StageKind::Elementwise { passes: 1 }, &["x"], &["c"])
        .stage(StageKind::Gate, &["a", "b"], &["ab"])
        .stage(StageKind::Gate, &["ab", "c"], &["out"])
        .with_outputs(&["out"]);
    let n = wide.stages.len();
    println!(
        "fused residency: {} regs/lane vs wave budget {}",
        wide.segment_regs(0, n),
        regalloc::wave_budget(&a, 1)
    );
    let wev = wide.evaluate(&a);
    println!(
        "planned: forced_split={}, {} passes, {:.1} us",
        wev.plan.forced_split,
        wev.plan.passes.len(),
        wev.perf.time_s * 1e6
    );
    for p in &wev.per_pass {
        println!("  pass {:<28} {:>8.1} us", p.name, p.time_s * 1e6);
    }

    println!("\n== 3. the same chains through the registry ==");
    for (label, q) in [
        ("add-rmsnorm", Query::add_rmsnorm(arch, 16 * 4096, 2048)),
        ("silu-mul", Query::silu_mul(arch, 16 * 4096, 2048)),
        ("qkv-rope", Query::qkv_rope(arch, 16, 16, 4096, 128)),
        ("gemm-epilogue", Query::gemm_epilogue(arch, 16 * 4096, 2048)),
    ] {
        let fused = q.dispatch().simulate();
        let split = q.unfused().dispatch().simulate();
        println!(
            "{label:<14} fused {:>8.1} us, split {:>8.1} us ({:.2}x)",
            fused.time_s * 1e6,
            split.time_s * 1e6,
            split.time_s / fused.time_s
        );
    }
}
