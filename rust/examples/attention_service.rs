//! Serving demo: Poisson arrivals through the L3 coordinators.
//!
//! Runs the registry-backed *mixed-op* service (attention + GEMM +
//! layernorm + RoPE in one queue, execution times from the autotuned
//! dispatch's cost model — no artifacts needed), then the
//! artifact-backed attention batching service when `make artifacts` has
//! produced a manifest. Reports throughput and latency percentiles.
//!
//! Run: `cargo run --release --example attention_service`

use hipkittens::coordinator::{
    mixed_trace, poisson_trace, BatchingService, MixedService, ServiceConfig,
};
use hipkittens::error::Result;
use hipkittens::kernels::registry::ArchId;
use hipkittens::runtime::{Manifest, Runtime};

fn main() -> Result<()> {
    println!("== mixed-op service (registry dispatch, simulated MI355X) ==");
    for rate in [50.0, 200.0, 1000.0] {
        let mut svc = MixedService::new(ArchId::Mi355x, ServiceConfig::default())?;
        let trace = mixed_trace(48, rate, 11);
        let rep = svc.run_trace(&trace)?;
        println!("\nrate {rate:>6.0} req/s -> {}", rep.summary());
        println!(
            "  batching amortization: mean batch {:.2} (1.0 = no batching)",
            rep.mean_batch
        );
    }

    let dir = std::env::var("HK_ARTIFACTS").unwrap_or_else(|_| "artifacts".into());
    if !Manifest::available(&dir) {
        println!("\n[artifact service skipped: run `make artifacts` first]");
        return Ok(());
    }
    println!("\n== artifact-backed attention service ==");
    let mut rt = Runtime::new(&dir)?;
    println!("backend: {}", rt.platform());
    for rate in [50.0, 200.0, 1000.0] {
        let mut svc = BatchingService::new(&mut rt, ServiceConfig::default())?;
        let trace = poisson_trace(48, rate, 11);
        let rep = svc.run_trace(&trace)?;
        println!("\nrate {rate:>6.0} req/s -> {}", rep.summary());
    }
    Ok(())
}
