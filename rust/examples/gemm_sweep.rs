//! GEMM sweep + chiplet-swizzle exploration (Fig. 6 / Table 4 workloads).
//!
//! Sweeps problem sizes across baselines — every HK launch resolved by
//! `registry::dispatch` — then sweeps Algorithm 1's (W, C) parameters at
//! a fixed shape, printing the L2/LLC trade-off surface the paper's
//! §3.4 describes.
//!
//! Run: `cargo run --release --example gemm_sweep`

use hipkittens::hk::topology::{render_first_round, ChipletSwizzle};
use hipkittens::kernels::baselines::{self, Baseline};
use hipkittens::kernels::gemm::{GridOrder, Pattern};
use hipkittens::kernels::registry::{ArchId, Query};
use hipkittens::sim::Dtype;

fn main() {
    let arch = ArchId::Mi355x;
    let a = arch.arch();

    println!("== BF16 GEMM sweep (TFLOPS) ==");
    print!("{:<12}", "M=N=K");
    for who in [Baseline::HK, Baseline::Aiter, Baseline::Triton] {
        print!("{:>14}", who.name());
    }
    println!();
    for s in [1024u32, 2048, 4096, 8192, 16384] {
        print!("{s:<12}");
        for who in [Baseline::HK, Baseline::Aiter, Baseline::Triton] {
            let d = Query::gemm(arch, Dtype::Bf16, s, s, s).dispatch();
            let p = baselines::gemm(&a, d.gemm_config(), who);
            print!("{:>14.0}", p.tflops);
        }
        println!();
    }

    println!("\n== Algorithm 1 (W, C) surface at 9216^3, tile 192x256 ==");
    println!(
        "{:<10} {:>6} {:>6} {:>9} {:>9}",
        "W/C", "L2%", "LLC%", "BW TB/s", "TFLOPS"
    );
    for w in [4u32, 5, 7, 8] {
        for c in [8u32, 25, 64, 216] {
            let p = Query::gemm(arch, Dtype::Bf16, 9216, 9216, 9216)
                .pattern(Pattern::PingPong8)
                .blocks(192, 256)
                .grid(GridOrder::Chiplet { window: w, chunk: c })
                .dispatch()
                .simulate();
            println!(
                "W{w}/C{c:<6} {:>5.0}% {:>5.0}% {:>9.1} {:>9.0}",
                p.l2_hit * 100.0,
                p.llc_hit * 100.0,
                p.eff_bw_tbps,
                p.tflops
            );
        }
    }

    println!("\n== First dispatch round, W5/C25 (Fig. 5c) ==");
    let swz = ChipletSwizzle::new(a.n_xcds, 5, 25);
    for line in render_first_round(&swz, 48, 48, 256).lines().take(20) {
        println!("{line}");
    }
}
