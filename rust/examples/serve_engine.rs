//! Serving-engine demo: a 512-request Poisson trace through the
//! continuous-batching engine over the paged KV cache, plus a decode
//! block-size ablation. Writes the `BENCH_serve.json` trajectory
//! (override the path with `HK_SERVE_OUT`) — the serving analog of the
//! dispatch bench's `BENCH_dispatch.json`.
//!
//! Everything runs on the trace clock against the kernel cost model, so
//! the output is bit-identical across runs (CI diffs it).
//!
//! Run: `cargo run --release --example serve_engine`

use hipkittens::error::Result;
use hipkittens::kernels::decode::block_ablation;
use hipkittens::runtime::json::Json;
use hipkittens::serve::{serve_trace, ServeConfig, ServeEngine};
use hipkittens::sim::arch::Dtype;

const REQUESTS: u64 = 512;
const RATE: f64 = 200.0;
const SEED: u64 = 7;

fn main() -> Result<()> {
    let cfg = ServeConfig::default();
    println!(
        "== paged serving engine (simulated {}, {} blocks x {} tokens, batch {}) ==",
        cfg.arch.tag(),
        cfg.num_blocks,
        cfg.block_size,
        cfg.max_batch
    );

    let trace = serve_trace(REQUESTS, RATE, SEED);
    let mut eng = ServeEngine::new(cfg.clone())?;
    let rep = eng.run_trace(&trace)?;
    println!("{}", rep.summary());
    println!(
        "  ttft p50 {:.2} ms | itl p50 {:.0} us | e2e p99 {:.1} ms | {} preemptions",
        rep.ttft.p50_us() / 1e3,
        rep.itl.p50_us(),
        rep.e2e.p99_us() / 1e3,
        rep.preemptions
    );

    println!("\n== decode block-size ablation (GQA, batch 32, ctx 32768) ==");
    // same arch the engine ran on, so the artifact is labelled truthfully
    let arch = cfg.arch.arch();
    let mut ablation_rows = Vec::new();
    for (blk, label, p) in block_ablation(&arch) {
        println!(
            "{label:<12} {:>10.1} us/step  {:>8.2} TB/s effective",
            p.time_s * 1e6,
            p.eff_bw_tbps
        );
        ablation_rows.push(Json::obj(vec![
            ("block", Json::Num(blk as f64)),
            ("step_us", Json::Num(p.time_s * 1e6)),
            ("eff_bw_tbps", Json::Num(p.eff_bw_tbps)),
        ]));
    }

    // KV dtype ablation: the same trace at an equal (deliberately
    // tight) per-GPU HBM budget — FP8 KV halves the bytes per block, so
    // the budget buys 2x the blocks and the admission/preemption
    // pressure drops accordingly
    println!("\n== KV dtype ablation (equal HBM budget, 1024 bf16 blocks) ==");
    let budget = 1024.0 * ServeConfig::default().kv_block_bytes();
    let mut kv_rows = Vec::new();
    for (label, dtype) in [("bf16", Dtype::Bf16), ("fp8", Dtype::Fp8)] {
        let kcfg = ServeConfig { kv_dtype: dtype, ..ServeConfig::default() }
            .with_kv_budget(budget);
        let mut e = ServeEngine::new(kcfg.clone())?;
        let r = e.run_trace(&trace)?;
        println!(
            "{label:<6} {:>6} blocks  preempt {:>4}  ttft p99 {:>9.2} ms  \
             {:>7.0} tok/s",
            kcfg.num_blocks,
            r.preemptions,
            r.ttft.p99_us() / 1e3,
            r.throughput_tok_s
        );
        kv_rows.push(Json::obj(vec![
            ("kv_dtype", Json::Str(label.into())),
            ("num_blocks", Json::Num(kcfg.num_blocks as f64)),
            ("preemptions", Json::Num(r.preemptions as f64)),
            ("ttft_p99_us", Json::Num(r.ttft.p99_us())),
            ("throughput_tok_s", Json::Num(r.throughput_tok_s)),
            ("peak_occupancy", Json::Num(r.peak_occupancy)),
        ]));
    }

    let doc = Json::obj(vec![
        ("bench", Json::Str("serve_engine".into())),
        ("kv_dtype_ablation", Json::Arr(kv_rows)),
        ("arch", Json::Str(cfg.arch.tag().into())),
        (
            "trace",
            Json::obj(vec![
                ("requests", Json::Num(REQUESTS as f64)),
                ("rate_rps", Json::Num(RATE)),
                ("seed", Json::Num(SEED as f64)),
            ]),
        ),
        (
            "config",
            Json::obj(vec![
                ("block_size", Json::Num(cfg.block_size as f64)),
                ("num_blocks", Json::Num(cfg.num_blocks as f64)),
                ("max_batch", Json::Num(cfg.max_batch as f64)),
                (
                    "shared_prefix_tokens",
                    Json::Num(cfg.shared_prefix_tokens as f64),
                ),
            ]),
        ),
        ("report", rep.to_json()),
        ("decode_block_ablation", Json::Arr(ablation_rows)),
    ]);
    let out = std::env::var("HK_SERVE_OUT")
        .unwrap_or_else(|_| "BENCH_serve.json".to_string());
    std::fs::write(&out, doc.dump())?;
    println!("\nwrote {out}");
    Ok(())
}
