//! MoE FFN walkthrough: router -> dispatch (alignment) -> grouped GEMM
//! through the autotuned registry, end to end on the cost model.
//!
//! The three stages mirror the amd-kernels MoE suite: top-k gating with
//! capacity/rerouting, the token permutation into expert-contiguous
//! ragged segments, and the `Op::MoeGemm` grouped kernel whose cost is
//! the max over chiplet-placed expert shards. A round-trip numerics
//! check (permute -> identity "experts" -> unpermute == input) runs on
//! real buffers, so the alignment path is exercised, not just printed.
//!
//! Run: `cargo run --release --example moe_ffn`

use hipkittens::error::Result;
use hipkittens::hk::tunecache::TuneCache;
use hipkittens::kernels::moe::dense_ffn_baseline;
use hipkittens::kernels::registry::{ArchId, Query};
use hipkittens::moe::{route, MoeConfig, MoeDispatchPlan};
use hipkittens::runtime::Rng;

const TOKENS: u32 = 4096;
const D: usize = 16; // round-trip check width (small on purpose)

fn main() -> Result<()> {
    let arch = ArchId::Mi355x;
    let cfg = MoeConfig::new(8, 2).with_skew(0.3);
    println!(
        "== MoE FFN walkthrough ({} tokens, {} experts, top-{}, skew {:.0}%) ==",
        TOKENS,
        cfg.experts,
        cfg.top_k,
        cfg.skew * 100.0
    );

    // 1. route
    let routing = route(&cfg, TOKENS);
    let s = &routing.stats;
    println!(
        "router: {} assignments, rerouted {}, dropped {}, \
         max/mean {:.2}, aux-imbalance {:.2}",
        s.assignments, s.rerouted, s.dropped_slots, s.max_over_mean, s.aux_imbalance
    );

    // 2. align into expert-contiguous ragged segments
    let plan = MoeDispatchPlan::new(&routing);
    println!("dispatch: {} ragged segments:", plan.segments.len());
    for seg in &plan.segments {
        println!(
            "  expert {:>2}: offset {:>5}, {:>5} tokens",
            seg.expert, seg.offset, seg.len
        );
    }

    // numerics round trip: identity experts must reconstruct the input
    let x = Rng::new(3).normal_vec(TOKENS as usize * D);
    let permuted = plan.permute(&routing, &x, D);
    let back = plan.unpermute(&routing, &permuted, D);
    let max_err = x
        .iter()
        .zip(&back)
        .map(|(a, b)| (a - b).abs())
        .fold(0.0f32, f32::max);
    println!("permute ∘ unpermute max |err| = {max_err:.2e}");
    assert!(max_err < 1e-4, "alignment round trip drifted: {max_err}");

    // 3. grouped GEMM through the registry (autotuned variant choice)
    let mut cache = TuneCache::new();
    println!("\n== grouped GEMM dispatch (d_model {}, d_ff {}) ==", cfg.d_model, cfg.d_ff);
    for (label, skew_pct) in [("balanced", 0u32), ("skew 40%", 40), ("skew 80%", 80)] {
        let q = Query::moe_gemm(
            arch,
            TOKENS,
            cfg.d_model,
            cfg.d_ff,
            cfg.experts,
            cfg.top_k,
            skew_pct,
        );
        let d = q.dispatch_with(&mut cache);
        let p = d.simulate();
        println!(
            "{label:<10} -> {:<16} {:>8.1} us  {:>7.0} TFLOPS hw",
            d.variant,
            p.time_s * 1e6,
            p.tflops
        );
    }

    let dense = dense_ffn_baseline(
        &arch.arch(),
        TOKENS,
        cfg.d_model,
        cfg.experts * cfg.d_ff,
    );
    println!(
        "dense iso-parameter baseline: {:>8.1} us  {:>7.0} TFLOPS",
        dense.time_s * 1e6,
        dense.tflops
    );
    println!("\n(run `hipkittens moe` for the full BENCH_moe.json sweep)");
    Ok(())
}
