"""L2 — the JAX transformer model built on the L1 Pallas kernels.

This is the paper's end-to-end validation workload (§4: "to validate
kernel stability, we use our kernels to pretrain Llama 1B and BERT 110M
..., matching the perplexity of models trained using PyTorch and AITER").
At reproduction scale we pretrain a small Llama-style decoder on a
synthetic corpus and check loss parity between the kernel path (Pallas
attention fwd+bwd) and the reference path (dense jnp attention).

The training step is exported over a *flat* parameter vector
(`ravel_pytree`), so the Rust coordinator can hold a single buffer and
step it without any Python in the loop.
"""

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax.flatten_util import ravel_pytree

from .kernels import attention as attn_k
from .kernels import ref as ref_k


@dataclass(frozen=True)
class ModelConfig:
    vocab: int = 2048
    d_model: int = 256
    n_layers: int = 4
    n_heads: int = 8
    n_kv_heads: int = 4
    d_head: int = 32
    seq_len: int = 128

    @property
    def qkv_dims(self):
        return (
            self.n_heads * self.d_head,
            self.n_kv_heads * self.d_head,
            self.n_kv_heads * self.d_head,
        )


def tiny_config() -> ModelConfig:
    """Small config for fast tests."""
    return ModelConfig(
        vocab=512, d_model=128, n_layers=2, n_heads=4, n_kv_heads=2,
        d_head=32, seq_len=64,
    )


def init_params(cfg: ModelConfig, key) -> dict:
    """Llama-style decoder parameters."""
    keys = jax.random.split(key, cfg.n_layers + 2)
    scale = 0.02

    def dense(k, m, n):
        return scale * jax.random.normal(k, (m, n), jnp.float32)

    dq, dkv, _ = cfg.qkv_dims
    layers = []
    for i in range(cfg.n_layers):
        lk = jax.random.split(keys[i], 8)
        layers.append({
            "ln1_w": jnp.ones(cfg.d_model, jnp.float32),
            "ln1_b": jnp.zeros(cfg.d_model, jnp.float32),
            "wq": dense(lk[0], cfg.d_model, dq),
            "wk": dense(lk[1], cfg.d_model, dkv),
            "wv": dense(lk[2], cfg.d_model, dkv),
            "wo": dense(lk[3], dq, cfg.d_model),
            "ln2_w": jnp.ones(cfg.d_model, jnp.float32),
            "ln2_b": jnp.zeros(cfg.d_model, jnp.float32),
            "w_up": dense(lk[4], cfg.d_model, 4 * cfg.d_model),
            "w_gate": dense(lk[5], cfg.d_model, 4 * cfg.d_model),
            "w_down": dense(lk[6], 4 * cfg.d_model, cfg.d_model),
        })
    return {
        "embed": scale * jax.random.normal(
            keys[-2], (cfg.vocab, cfg.d_model), jnp.float32),
        "ln_f_w": jnp.ones(cfg.d_model, jnp.float32),
        "ln_f_b": jnp.zeros(cfg.d_model, jnp.float32),
        "layers": layers,
    }


def _layernorm(x, w, b, eps=1e-5):
    mean = x.mean(-1, keepdims=True)
    c = x - mean
    var = (c * c).mean(-1, keepdims=True)
    return c * jax.lax.rsqrt(var + eps) * w + b


def _rope(x, theta=10000.0):
    """Differentiable RoPE matching kernels.rope (the Pallas version is
    exported separately for the serving path)."""
    return ref_k.rope(x, theta=theta)


def _block(cfg: ModelConfig, p, x, use_kernels: bool):
    b, t, _ = x.shape
    h = _layernorm(x, p["ln1_w"], p["ln1_b"])
    q = (h @ p["wq"]).reshape(b, t, cfg.n_heads, cfg.d_head).transpose(0, 2, 1, 3)
    k = (h @ p["wk"]).reshape(b, t, cfg.n_kv_heads, cfg.d_head).transpose(0, 2, 1, 3)
    v = (h @ p["wv"]).reshape(b, t, cfg.n_kv_heads, cfg.d_head).transpose(0, 2, 1, 3)
    q, k = _rope(q), _rope(k)
    if use_kernels:
        bq = min(64, t)
        o = attn_k.attention(q, k, v, True, None, bq, bq)
    else:
        o = ref_k.attention(q, k, v, causal=True)
    o = o.transpose(0, 2, 1, 3).reshape(b, t, -1)
    x = x + o @ p["wo"]
    h = _layernorm(x, p["ln2_w"], p["ln2_b"])
    mlp = (jax.nn.silu(h @ p["w_gate"]) * (h @ p["w_up"])) @ p["w_down"]
    return x + mlp


def forward(cfg: ModelConfig, params, tokens, use_kernels: bool = True):
    """Logits for int32 tokens (B, T)."""
    x = params["embed"][tokens]
    for p in params["layers"]:
        x = _block(cfg, p, x, use_kernels)
    x = _layernorm(x, params["ln_f_w"], params["ln_f_b"])
    return x @ params["embed"].T


def loss_fn(cfg: ModelConfig, params, batch, use_kernels: bool = True):
    """Next-token cross entropy; ``batch`` is int32 (B, T+1)."""
    tokens, targets = batch[:, :-1], batch[:, 1:]
    logits = forward(cfg, params, tokens, use_kernels)
    logp = jax.nn.log_softmax(logits, axis=-1)
    ll = jnp.take_along_axis(logp, targets[..., None], axis=-1)
    return -ll.mean()


# ---------------------------------------------------------------- flat API


def flat_spec(cfg: ModelConfig):
    """(n_params, unravel) for the flat-vector API."""
    params = init_params(cfg, jax.random.PRNGKey(0))
    flat, unravel = ravel_pytree(params)
    return flat.shape[0], unravel


def make_flat_fns(cfg: ModelConfig, lr: float = 0.05, momentum: float = 0.9):
    """Build the flat-parameter entry points the Rust runtime drives.

    Returns a dict of jittable functions:
      init(seed)                       -> (flat,)
      train_step(flat, mom, batch)     -> (flat', mom', loss)  [kernel path]
      train_step_ref(flat, mom, batch) -> same on the reference path
      lm_loss(flat, batch)             -> (loss,)              [kernel path]
    """
    _, unravel = flat_spec(cfg)

    def init(seed):
        key = jax.random.PRNGKey(seed[0])
        flat, _ = ravel_pytree(init_params(cfg, key))
        return (flat,)

    def _step(flat, mom, batch, use_kernels):
        params = unravel(flat)
        loss, grads = jax.value_and_grad(
            lambda p: loss_fn(cfg, p, batch, use_kernels))(params)
        gflat, _ = ravel_pytree(grads)
        mom2 = momentum * mom + gflat
        return flat - lr * mom2, mom2, loss

    def train_step(flat, mom, batch):
        return _step(flat, mom, batch, True)

    def train_step_ref(flat, mom, batch):
        return _step(flat, mom, batch, False)

    def lm_loss(flat, batch):
        return (loss_fn(cfg, unravel(flat), batch, True),)

    return {
        "init": init,
        "train_step": train_step,
        "train_step_ref": train_step_ref,
        "lm_loss": lm_loss,
    }


def synthetic_batch(cfg: ModelConfig, key, batch_size: int):
    """Synthetic corpus: token sequences from a noisy drifting source —
    structured enough for the loss to fall well below uniform."""
    k1, k2 = jax.random.split(key)
    base = jax.random.randint(
        k1, (batch_size, cfg.seq_len + 1), 0, cfg.vocab // 4)
    drift = jnp.cumsum(
        jax.random.randint(k2, (batch_size, cfg.seq_len + 1), 0, 3), axis=1)
    return ((base + drift) % cfg.vocab).astype(jnp.int32)
