"""AOT pipeline: lower every entry point to HLO *text* + a manifest.

Interchange format is HLO text, NOT serialized HloModuleProto: jax >= 0.5
emits protos with 64-bit instruction ids which the Rust side's
xla_extension 0.5.1 rejects; the text parser reassigns ids (see
/opt/xla-example/README.md). Each entry is lowered with
``return_tuple=True`` so the Rust runtime always unwraps a tuple.

Run as ``python -m compile.aot --out ../artifacts`` (the Makefile's
`artifacts` target). Python never runs after this point — the Rust binary
is self-contained.
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model as model_mod
from .kernels import attention as attn_k
from .kernels import gemm as gemm_k
from .kernels import layernorm as ln_k
from .kernels import rope as rope_k

SERVICE_BATCHES = (1, 2, 4, 8)
SERVICE_HEADS = 8
SERVICE_KV_HEADS = 4
SERVICE_SEQ = 256
SERVICE_DHEAD = 64


def to_hlo_text(lowered) -> str:
    """StableHLO -> XlaComputation -> HLO text (ids reassigned)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def _spec(shape, dtype):
    return jax.ShapeDtypeStruct(shape, dtype)


def _meta(args, outs):
    def one(s):
        return {"shape": list(s.shape), "dtype": str(s.dtype)}

    return {"inputs": [one(a) for a in args], "outputs": [one(o) for o in outs]}


def entries(cfg: model_mod.ModelConfig):
    """(name, fn, example_args, extra_meta) for every artifact."""
    out = []

    # --- quickstart GEMM (the paper's Fig. 6 workload, small) ---------
    def gemm256(a, b):
        return (gemm_k.matmul(a, b, block_m=64, block_n=64, block_k=64),)

    out.append((
        "gemm256",
        gemm256,
        (_spec((256, 256), jnp.float32), _spec((256, 256), jnp.float32)),
        {"kind": "gemm", "m": 256, "n": 256, "k": 256},
    ))

    # --- attention forward at several batch sizes (serving path) ------
    for b in SERVICE_BATCHES:
        def attn_fwd(q, k, v):
            return (attn_k.attention(q, k, v, False, None, 64, 64),)

        out.append((
            f"attn_fwd_b{b}",
            attn_fwd,
            (
                _spec((b, SERVICE_HEADS, SERVICE_SEQ, SERVICE_DHEAD), jnp.float32),
                _spec((b, SERVICE_KV_HEADS, SERVICE_SEQ, SERVICE_DHEAD), jnp.float32),
                _spec((b, SERVICE_KV_HEADS, SERVICE_SEQ, SERVICE_DHEAD), jnp.float32),
            ),
            {
                "kind": "attention",
                "batch": b,
                "heads": SERVICE_HEADS,
                "kv_heads": SERVICE_KV_HEADS,
                "seq": SERVICE_SEQ,
                "d_head": SERVICE_DHEAD,
            },
        ))

    # --- memory-bound kernels (Fig. 9 workloads) ----------------------
    def fused_ln(x, res, w, bias):
        o, r = ln_k.fused_dropout_residual_layernorm(
            x, res, w, bias, p=0.1, seed=13)
        return (o, r)

    rows, d = 256, 128
    out.append((
        "fused_layernorm",
        fused_ln,
        (
            _spec((rows, d), jnp.float32),
            _spec((rows, d), jnp.float32),
            _spec((d,), jnp.float32),
            _spec((d,), jnp.float32),
        ),
        {"kind": "layernorm", "rows": rows, "d": d, "p": 0.1, "seed": 13},
    ))

    def rope_fn(x):
        return (rope_k.rope(x),)

    out.append((
        "rope",
        rope_fn,
        (_spec((2, SERVICE_HEADS, SERVICE_SEQ, SERVICE_DHEAD), jnp.float32),),
        {"kind": "rope"},
    ))

    # --- training entry points (flat-parameter API) -------------------
    n_params, _ = model_mod.flat_spec(cfg)
    fns = model_mod.make_flat_fns(cfg)
    batch_shape = (4, cfg.seq_len + 1)
    flat = _spec((n_params,), jnp.float32)
    batch = _spec(batch_shape, jnp.int32)

    out.append((
        "init_params",
        fns["init"],
        (_spec((1,), jnp.int32),),
        {"kind": "init", "n_params": n_params},
    ))
    model_meta = {
        "n_params": n_params,
        "vocab": cfg.vocab,
        "d_model": cfg.d_model,
        "n_layers": cfg.n_layers,
        "n_heads": cfg.n_heads,
        "n_kv_heads": cfg.n_kv_heads,
        "seq_len": cfg.seq_len,
        "batch": batch_shape[0],
    }
    out.append((
        "train_step",
        fns["train_step"],
        (flat, flat, batch),
        {"kind": "train_step", **model_meta},
    ))
    out.append((
        "train_step_ref",
        fns["train_step_ref"],
        (flat, flat, batch),
        {"kind": "train_step", **model_meta, "path": "reference"},
    ))
    out.append((
        "lm_loss",
        fns["lm_loss"],
        (flat, batch),
        {"kind": "loss", **model_meta},
    ))
    return out


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out", default="../artifacts")
    ap.add_argument("--only", default=None, help="comma-list of entries")
    args = ap.parse_args()
    os.makedirs(args.out, exist_ok=True)

    cfg = model_mod.ModelConfig()
    manifest = {"model": cfg.__dict__, "entries": []}
    only = set(args.only.split(",")) if args.only else None

    for name, fn, specs, extra in entries(cfg):
        if only and name not in only:
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = to_hlo_text(lowered)
        fname = f"{name}.hlo.txt"
        with open(os.path.join(args.out, fname), "w") as f:
            f.write(text)
        outs = jax.eval_shape(fn, *specs)
        entry = {"name": name, "file": fname, **_meta(specs, outs), "meta": extra}
        manifest["entries"].append(entry)
        print(f"  lowered {name:18s} -> {fname} ({len(text)//1024} KiB)")

    with open(os.path.join(args.out, "manifest.json"), "w") as f:
        json.dump(manifest, f, indent=1)
    print(f"wrote {len(manifest['entries'])} entries to {args.out}/manifest.json")


if __name__ == "__main__":
    main()
