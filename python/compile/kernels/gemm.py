"""Tiled GEMM Pallas kernel.

Hardware adaptation (paper -> TPU-style Pallas, see DESIGN.md
§Hardware-Adaptation): the paper's GEMM computes a 256x256 output tile per
thread block, double-buffering 64-wide K slabs HBM->LDS->registers under an
8-wave ping-pong schedule. Under Pallas the same decomposition is expressed
with an (m, n, k) grid and BlockSpecs: the BlockSpec index maps *are* the
HBM<->VMEM schedule (Pallas pipelines the k-slabs), and the MXU plays the
role of the MFMA pipes. Accumulation is always f32 (the paper's `rt_fl`
accumulators), whatever the input dtype.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _gemm_kernel(a_ref, b_ref, o_ref, *, n_k: int):
    """One (bm x bk) @ (bk x bn) step accumulated into the f32 output."""
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    o_ref[...] += jax.lax.dot_general(
        a, b, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32
    )


@functools.partial(
    jax.jit, static_argnames=("block_m", "block_n", "block_k", "out_dtype")
)
def matmul(
    a: jax.Array,
    b: jax.Array,
    *,
    block_m: int = 128,
    block_n: int = 128,
    block_k: int = 128,
    out_dtype=None,
) -> jax.Array:
    """``a @ b`` with shapes (M, K) x (K, N); M/N/K multiples of the blocks.

    Inputs may be bf16 or f32; the kernel accumulates in f32 and casts to
    ``out_dtype`` (defaults to the input dtype) at the end.
    """
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, f"inner dims {k} != {k2}"
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        f"({m},{n},{k}) not multiples of ({block_m},{block_n},{block_k})"
    )
    if out_dtype is None:
        out_dtype = a.dtype
    n_k = k // block_k
    out = pl.pallas_call(
        functools.partial(_gemm_kernel, n_k=n_k),
        grid=(m // block_m, n // block_n, n_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), jnp.float32),
        interpret=True,
    )(a, b)
    return out.astype(out_dtype)


def pick_blocks(m: int, n: int, k: int) -> tuple[int, int, int]:
    """Choose block sizes for a problem (largest power-of-two divisors
    capped at 128 — the VMEM-friendly analog of the paper's 256x256 LDS
    tiles)."""

    def best(dim: int) -> int:
        b = 1
        while b < 128 and dim % (b * 2) == 0:
            b *= 2
        return b

    return best(m), best(n), best(k)
