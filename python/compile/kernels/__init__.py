"""L1 Pallas kernels (interpret=True) — the numeric plane of the HipKittens
reproduction.

Each kernel mirrors one of the paper's evaluated workloads:

- ``gemm``        — tiled GEMM (paper Fig. 6 / 14 workload)
- ``attention``   — flash attention forward/backward, MHA/GQA,
                    causal/non-causal (Figs. 7/8/15/16/17)
- ``layernorm``   — fused dropout + residual + layernorm (Fig. 9, E.2)
- ``rope``        — rotary positional embedding (Fig. 9)
- ``ref``         — pure-jnp oracles for all of the above

All kernels run under ``interpret=True`` so they lower to plain HLO and
execute on the CPU PJRT client that the Rust runtime drives (real-TPU
lowering emits Mosaic custom-calls the CPU plugin cannot run).
"""

from . import attention, gemm, layernorm, ref, rope  # noqa: F401
