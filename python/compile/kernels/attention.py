"""Flash-attention forward + backward Pallas kernels (MHA/GQA,
causal/non-causal) with a custom VJP, mirroring the paper's attention
kernels (Figs. 7/8/15/16/17, listing E.3).

Hardware adaptation: the paper's 8-wave ping-pong streams K/V tiles
HBM->LDS while compute waves run QK/AV MFMAs interleaved with online-
softmax VALU ops. Here the same loop structure appears as a Pallas grid
over (batch, q-head, q-block) with an in-kernel `fori_loop` over KV blocks
doing online softmax; the BlockSpec pipeline plays the role of the K/V
double buffer. GQA maps G query heads onto one KV head via the BlockSpec
index map (the paper's `head_idx_kv = head_idx / GROUP_SIZE`).

The backward pass uses the standard recompute (FlashAttention-2 style)
split: a dKV kernel iterating over Q blocks and a dQ kernel iterating over
KV blocks, both consuming the forward LSE — the same multi-matmul,
register-heavy structure the paper tames with pinned AGPR tiles.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _fwd_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, *, block_k: int,
                causal: bool, sm_scale: float):
    """One (block_q x d) output tile; loops over KV blocks."""
    block_q, d = q_ref.shape[-2], q_ref.shape[-1]
    seq_k = k_ref.shape[-2]
    q_idx = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * sm_scale  # (bq, d)

    def body(i, carry):
        acc, m_prev, l_prev = carry
        k = k_ref[0, 0, pl.dslice(i * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[0, 0, pl.dslice(i * block_k, block_k), :].astype(jnp.float32)
        s = q @ k.T  # (bq, bk)
        if causal:
            q_pos = q_idx * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            k_pos = i * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        m_cur = jnp.maximum(m_prev, s.max(axis=-1))
        correction = jnp.exp(m_prev - m_cur)
        p = jnp.exp(s - m_cur[:, None])
        l_cur = l_prev * correction + p.sum(axis=-1)
        acc = acc * correction[:, None] + p @ v
        return acc, m_cur, l_cur

    acc0 = jnp.zeros((block_q, d), jnp.float32)
    m0 = jnp.full((block_q,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((block_q,), jnp.float32)
    acc, m, l = jax.lax.fori_loop(0, seq_k // block_k, body, (acc0, m0, l0))
    l = jnp.maximum(l, 1e-30)
    o_ref[0, 0] = (acc / l[:, None]).astype(o_ref.dtype)
    lse_ref[0, 0] = m + jnp.log(l)


def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref, dq_ref,
                   *, block_k: int, causal: bool, sm_scale: float):
    """dQ for one (block_q x d) tile; loops over KV blocks (recompute P)."""
    block_q, d = q_ref.shape[-2], q_ref.shape[-1]
    seq_k = k_ref.shape[-2]
    q_idx = pl.program_id(2)
    q = q_ref[0, 0].astype(jnp.float32) * sm_scale
    do = do_ref[0, 0].astype(jnp.float32)
    lse = lse_ref[0, 0]
    delta = delta_ref[0, 0]

    def body(i, dq):
        k = k_ref[0, 0, pl.dslice(i * block_k, block_k), :].astype(jnp.float32)
        v = v_ref[0, 0, pl.dslice(i * block_k, block_k), :].astype(jnp.float32)
        s = q @ k.T
        if causal:
            q_pos = q_idx * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            k_pos = i * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])
        dp = do @ v.T
        ds = p * (dp - delta[:, None])
        return dq + ds @ k

    dq0 = jnp.zeros((block_q, d), jnp.float32)
    dq = jax.lax.fori_loop(0, seq_k // block_k, body, dq0)
    dq_ref[0, 0] = (dq * sm_scale).astype(dq_ref.dtype)


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                    dk_ref, dv_ref, *, block_q: int, causal: bool,
                    sm_scale: float):
    """dK/dV for one (block_k x d) tile; loops over Q blocks."""
    block_k, d = k_ref.shape[-2], k_ref.shape[-1]
    seq_q = q_ref.shape[-2]
    k_idx = pl.program_id(2)
    k = k_ref[0, 0].astype(jnp.float32)
    v = v_ref[0, 0].astype(jnp.float32)

    def body(i, carry):
        dk, dv = carry
        q = q_ref[0, 0, pl.dslice(i * block_q, block_q), :].astype(
            jnp.float32) * sm_scale
        do = do_ref[0, 0, pl.dslice(i * block_q, block_q), :].astype(
            jnp.float32)
        lse = lse_ref[0, 0, pl.dslice(i * block_q, block_q)]
        delta = delta_ref[0, 0, pl.dslice(i * block_q, block_q)]
        s = q @ k.T  # (bq, bk)
        if causal:
            q_pos = i * block_q + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 0)
            k_pos = k_idx * block_k + jax.lax.broadcasted_iota(
                jnp.int32, (block_q, block_k), 1)
            s = jnp.where(q_pos >= k_pos, s, NEG_INF)
        p = jnp.exp(s - lse[:, None])  # (bq, bk)
        dv = dv + p.T @ do
        dp = do @ v.T  # (bq, bk)
        ds = p * (dp - delta[:, None])
        dk = dk + ds.T @ q
        return dk, dv

    dk0 = jnp.zeros((block_k, d), jnp.float32)
    dv0 = jnp.zeros((block_k, d), jnp.float32)
    dk, dv = jax.lax.fori_loop(0, seq_q // block_q, body, (dk0, dv0))
    dk_ref[0, 0] = dk.astype(dk_ref.dtype)
    dv_ref[0, 0] = dv.astype(dv_ref.dtype)


def _fwd_impl(q, k, v, *, causal, sm_scale, block_q, block_k):
    b, hq, n, d = q.shape
    hkv = k.shape[1]
    assert hq % hkv == 0, f"GQA needs hq % hkv == 0, got {hq} {hkv}"
    g = hq // hkv
    grid = (b, hq, n // block_q)
    kern = functools.partial(
        _fwd_kernel, block_k=block_k, causal=causal, sm_scale=sm_scale)
    o, lse = pl.pallas_call(
        kern,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bi, hi, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, n, d), lambda bi, hi, qi, g=g: (bi, hi // g, 0, 0)),
            pl.BlockSpec((1, 1, n, d), lambda bi, hi, qi, g=g: (bi, hi // g, 0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bi, hi, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_q), lambda bi, hi, qi: (bi, hi, qi)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, n, d), q.dtype),
            jax.ShapeDtypeStruct((b, hq, n), jnp.float32),
        ],
        interpret=True,
    )(q, k, v)
    return o, lse


@functools.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6))
def _attention(q, k, v, causal, sm_scale, block_q, block_k):
    o, _ = _fwd_impl(
        q, k, v,
        causal=causal,
        sm_scale=_scale(sm_scale, q.shape[-1]),
        block_q=block_q,
        block_k=block_k,
    )
    return o


def attention(q, k, v, causal=False, sm_scale=None, block_q=64, block_k=64):
    """Flash attention over (B, H, N, D) tensors.

    ``k``/``v`` may have fewer heads than ``q`` (GQA); ``sm_scale``
    defaults to 1/sqrt(D). Differentiable via the Pallas backward kernels
    (custom VJP — the nondiff config must stay positional, hence this
    wrapper).
    """
    return _attention(q, k, v, causal, sm_scale, block_q, block_k)


def _scale(sm_scale, d):
    return (1.0 / (d ** 0.5)) if sm_scale is None else sm_scale


def _attention_fwd(q, k, v, causal, sm_scale, block_q, block_k):
    o, lse = _fwd_impl(
        q, k, v,
        causal=causal,
        sm_scale=_scale(sm_scale, q.shape[-1]),
        block_q=block_q,
        block_k=block_k,
    )
    return o, (q, k, v, o, lse)


def _attention_bwd(causal, sm_scale, block_q, block_k, res, do):
    q, k, v, o, lse = res
    b, hq, n, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    scale = _scale(sm_scale, d)
    # delta = rowsum(dO * O) — the paper's epilogue vector
    delta = jnp.sum(do.astype(jnp.float32) * o.astype(jnp.float32), axis=-1)

    dq = pl.pallas_call(
        functools.partial(
            _bwd_dq_kernel, block_k=block_k, causal=causal, sm_scale=scale),
        grid=(b, hq, n // block_q),
        in_specs=[
            pl.BlockSpec((1, 1, block_q, d), lambda bi, hi, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, n, d), lambda bi, hi, qi, g=g: (bi, hi // g, 0, 0)),
            pl.BlockSpec((1, 1, n, d), lambda bi, hi, qi, g=g: (bi, hi // g, 0, 0)),
            pl.BlockSpec((1, 1, block_q, d), lambda bi, hi, qi: (bi, hi, qi, 0)),
            pl.BlockSpec((1, 1, block_q), lambda bi, hi, qi: (bi, hi, qi)),
            pl.BlockSpec((1, 1, block_q), lambda bi, hi, qi: (bi, hi, qi)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_q, d), lambda bi, hi, qi: (bi, hi, qi, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hq, n, d), q.dtype),
        interpret=True,
    )(q, k, v, do, lse, delta)

    # dK/dV per q-head, then reduce over the GQA group (L2-level sum).
    dk_h, dv_h = pl.pallas_call(
        functools.partial(
            _bwd_dkv_kernel, block_q=block_q, causal=causal, sm_scale=scale),
        grid=(b, hq, n // block_k),
        in_specs=[
            pl.BlockSpec((1, 1, n, d), lambda bi, hi, ki: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda bi, hi, ki, g=g: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda bi, hi, ki, g=g: (bi, hi // g, ki, 0)),
            pl.BlockSpec((1, 1, n, d), lambda bi, hi, ki: (bi, hi, 0, 0)),
            pl.BlockSpec((1, 1, n), lambda bi, hi, ki: (bi, hi, 0)),
            pl.BlockSpec((1, 1, n), lambda bi, hi, ki: (bi, hi, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, 1, block_k, d), lambda bi, hi, ki: (bi, hi, ki, 0)),
            pl.BlockSpec((1, 1, block_k, d), lambda bi, hi, ki: (bi, hi, ki, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((b, hq, n, d), jnp.float32),
            jax.ShapeDtypeStruct((b, hq, n, d), jnp.float32),
        ],
        interpret=True,
    )(q, k, v, do, lse, delta)

    dk = dk_h.reshape(b, hkv, g, n, d).sum(axis=2).astype(k.dtype)
    dv = dv_h.reshape(b, hkv, g, n, d).sum(axis=2).astype(v.dtype)
    return dq, dk, dv


_attention.defvjp(_attention_fwd, _attention_bwd)
