"""Rotary positional embedding (RoPE) Pallas kernel (paper Fig. 9).

Applies the rotation to (B, H, N, D) query/key tensors with the
half-split convention: for pairs (x1, x2) = (x[..., :D/2], x[..., D/2:]),

    out1 = x1 * cos - x2 * sin
    out2 = x2 * cos + x1 * sin

with angle(pos, i) = pos / theta^(2i/D). Purely memory-bound — the
workload the paper uses to show HK's bulk vector ops beat torch.compile.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rope_kernel(x_ref, o_ref, *, theta: float, block_n: int, d: int):
    n_idx = pl.program_id(2)
    x = x_ref[0, 0].astype(jnp.float32)  # (block_n, d)
    half = d // 2
    pos = n_idx * block_n + jax.lax.broadcasted_iota(
        jnp.float32, (block_n, half), 0)
    dim = jax.lax.broadcasted_iota(jnp.float32, (block_n, half), 1)
    inv_freq = jnp.exp(-(2.0 * dim / d) * jnp.log(theta))
    ang = pos * inv_freq
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    x1, x2 = x[:, :half], x[:, half:]
    o = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    o_ref[0, 0] = o.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("theta", "block_n"))
def rope(x: jax.Array, *, theta: float = 10000.0, block_n: int = 64):
    """RoPE over (B, H, N, D); N must be a multiple of ``block_n``,
    D even."""
    b, h, n, d = x.shape
    assert d % 2 == 0 and n % block_n == 0
    return pl.pallas_call(
        functools.partial(_rope_kernel, theta=theta, block_n=block_n, d=d),
        grid=(b, h, n // block_n),
        in_specs=[
            pl.BlockSpec((1, 1, block_n, d), lambda bi, hi, ni: (bi, hi, ni, 0))
        ],
        out_specs=pl.BlockSpec(
            (1, 1, block_n, d), lambda bi, hi, ni: (bi, hi, ni, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h, n, d), x.dtype),
        interpret=True,
    )(x)
