"""Pure-jnp correctness oracles for every L1 kernel.

These are the ground truth the pytest suite checks the Pallas kernels
against (the paper's correctness protocol: every HK kernel is validated
against a straightforward reference implementation).
"""

import jax
import jax.numpy as jnp


def matmul(a, b, out_dtype=None):
    """Plain f32-accumulated matmul."""
    if out_dtype is None:
        out_dtype = a.dtype
    out = jnp.dot(
        a.astype(jnp.float32),
        b.astype(jnp.float32),
        preferred_element_type=jnp.float32,
    )
    return out.astype(out_dtype)


def attention(q, k, v, causal=False, sm_scale=None):
    """Dense softmax attention over (B, H, N, D); supports GQA by
    broadcasting KV heads."""
    b, hq, n, d = q.shape
    hkv = k.shape[1]
    g = hq // hkv
    if g > 1:
        k = jnp.repeat(k, g, axis=1)
        v = jnp.repeat(v, g, axis=1)
    scale = (1.0 / (d ** 0.5)) if sm_scale is None else sm_scale
    s = jnp.einsum(
        "bhqd,bhkd->bhqk",
        q.astype(jnp.float32),
        k.astype(jnp.float32),
    ) * scale
    if causal:
        mask = jnp.tril(jnp.ones((n, n), bool))
        s = jnp.where(mask[None, None], s, -1e30)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32))
    return o.astype(q.dtype)


def dropout(x, seed, p, rows, d):
    """The kernel's counter-based dropout, replicated exactly."""
    from .layernorm import dropout_mask

    if p <= 0.0:
        return x
    flat = jnp.arange(rows * d, dtype=jnp.uint32).reshape(rows, d)
    keep = dropout_mask(flat, seed, p)
    return jnp.where(keep, x / (1.0 - p), 0.0)


def fused_dropout_residual_layernorm(
    x, residual, weight, bias, p=0.0, seed=0, eps=1e-5
):
    rows, d = x.shape
    xf = dropout(x.astype(jnp.float32), seed, p, rows, d)
    resid = residual.astype(jnp.float32) + xf
    mean = resid.mean(axis=-1, keepdims=True)
    centered = resid - mean
    var = (centered * centered).mean(axis=-1, keepdims=True)
    normed = centered * jax.lax.rsqrt(var + eps)
    o = normed * weight.astype(jnp.float32) + bias.astype(jnp.float32)
    return o.astype(x.dtype), resid.astype(x.dtype)


def rope(x, theta=10000.0):
    b, h, n, d = x.shape
    half = d // 2
    pos = jnp.arange(n, dtype=jnp.float32)[:, None]
    dim = jnp.arange(half, dtype=jnp.float32)[None, :]
    inv_freq = jnp.exp(-(2.0 * dim / d) * jnp.log(theta))
    ang = pos * inv_freq  # (n, half)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    xf = x.astype(jnp.float32)
    x1, x2 = xf[..., :half], xf[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)
