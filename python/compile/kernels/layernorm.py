"""Fused dropout + residual + layernorm Pallas kernel (paper Fig. 9,
listing E.2 — the prenorm-Transformer memory-bound workload).

The kernel processes a chunk of sequence vectors per grid step, fusing:

    resid_out = residual + dropout(x, p)
    o         = layernorm(resid_out) * weight + bias

Dropout uses a counter-based hash of the flat element index (a stateless
xorshift-style mix), so the oracle in `ref.py` reproduces it bit-exactly —
the kernel stays a pure function of its inputs, as required for AOT
export.
"""

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _hash_u32(x):
    """Deterministic 32-bit mix (xorshift* flavored), vectorized."""
    x = x.astype(jnp.uint32)
    x = x ^ (x >> 16)
    x = x * jnp.uint32(0x7FEB352D)
    x = x ^ (x >> 15)
    x = x * jnp.uint32(0x846CA68B)
    x = x ^ (x >> 16)
    return x


def dropout_mask(flat_idx, seed: int, p: float):
    """keep-mask for dropout probability ``p`` from hashed indices."""
    h = _hash_u32(flat_idx + jnp.uint32(seed) * jnp.uint32(0x9E3779B9))
    threshold = jnp.uint32(int(p * 0xFFFFFFFF)) if p > 0 else jnp.uint32(0)
    return h >= threshold


def _ln_kernel(x_ref, res_ref, w_ref, b_ref, o_ref, resid_ref, *,
               p: float, seed: int, eps: float, d: int, block: int):
    row0 = pl.program_id(0) * block
    x = x_ref[...].astype(jnp.float32)  # (block, d)
    res = res_ref[...].astype(jnp.float32)
    if p > 0.0:
        rows = row0 + jax.lax.broadcasted_iota(jnp.int32, (block, d), 0)
        cols = jax.lax.broadcasted_iota(jnp.int32, (block, d), 1)
        flat = (rows * d + cols).astype(jnp.uint32)
        keep = dropout_mask(flat, seed, p)
        x = jnp.where(keep, x / (1.0 - p), 0.0)
    resid = res + x
    resid_ref[...] = resid.astype(resid_ref.dtype)
    mean = resid.mean(axis=-1, keepdims=True)
    centered = resid - mean
    var = (centered * centered).mean(axis=-1, keepdims=True)
    normed = centered * jax.lax.rsqrt(var + eps)
    w = w_ref[...].astype(jnp.float32)
    b = b_ref[...].astype(jnp.float32)
    o_ref[...] = (normed * w + b).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("p", "seed", "eps", "block"))
def fused_dropout_residual_layernorm(
    x: jax.Array,
    residual: jax.Array,
    weight: jax.Array,
    bias: jax.Array,
    *,
    p: float = 0.0,
    seed: int = 0,
    eps: float = 1e-5,
    block: int = 32,
):
    """Returns ``(o, resid_out)`` over (rows, d) inputs.

    ``rows`` must be a multiple of ``block``; callers flatten
    (batch, seq) -> rows, matching the kernel's per-thread-block chunk of
    sequence vectors (listing E.2).
    """
    rows, d = x.shape
    assert rows % block == 0, f"rows {rows} % block {block}"
    kern = functools.partial(
        _ln_kernel, p=p, seed=seed, eps=eps, d=d, block=block)
    return pl.pallas_call(
        kern,
        grid=(rows // block,),
        in_specs=[
            pl.BlockSpec((block, d), lambda i: (i, 0)),
            pl.BlockSpec((block, d), lambda i: (i, 0)),
            pl.BlockSpec((d,), lambda i: (0,)),
            pl.BlockSpec((d,), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block, d), lambda i: (i, 0)),
            pl.BlockSpec((block, d), lambda i: (i, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((rows, d), x.dtype),
            jax.ShapeDtypeStruct((rows, d), x.dtype),
        ],
        interpret=True,
    )(x, residual, weight, bias)
