"""Memory-bound kernels (fused dropout-residual-layernorm, RoPE) vs
oracles."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import layernorm, ref, rope

SETTINGS = dict(deadline=None, max_examples=10,
                suppress_health_check=[hypothesis.HealthCheck.too_slow])


def _xw(rows, d, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (rows, d), jnp.float32)
    res = jax.random.normal(ks[1], (rows, d), jnp.float32)
    w = 1.0 + 0.1 * jax.random.normal(ks[2], (d,), jnp.float32)
    b = 0.1 * jax.random.normal(ks[3], (d,), jnp.float32)
    return x, res, w, b


@pytest.mark.parametrize("p", [0.0, 0.1, 0.5])
def test_fused_ln_matches_ref(p):
    x, res, w, b = _xw(64, 128)
    o1, r1 = layernorm.fused_dropout_residual_layernorm(
        x, res, w, b, p=p, seed=42)
    o2, r2 = ref.fused_dropout_residual_layernorm(x, res, w, b, p=p, seed=42)
    np.testing.assert_allclose(o1, o2, atol=1e-4, rtol=1e-3)
    np.testing.assert_allclose(r1, r2, atol=1e-5)


def test_dropout_keep_rate_close_to_1_minus_p():
    x = jnp.ones((256, 256), jnp.float32)
    res = jnp.zeros_like(x)
    w, b = jnp.ones(256), jnp.zeros(256)
    _, r = layernorm.fused_dropout_residual_layernorm(
        x, res, w, b, p=0.3, seed=5)
    keep_rate = float((r != 0).mean())
    assert abs(keep_rate - 0.7) < 0.02, keep_rate


def test_dropout_deterministic_per_seed():
    x, res, w, b = _xw(64, 64, seed=1)
    o1, _ = layernorm.fused_dropout_residual_layernorm(
        x, res, w, b, p=0.2, seed=9)
    o2, _ = layernorm.fused_dropout_residual_layernorm(
        x, res, w, b, p=0.2, seed=9)
    o3, _ = layernorm.fused_dropout_residual_layernorm(
        x, res, w, b, p=0.2, seed=10)
    np.testing.assert_array_equal(o1, o2)
    assert not np.allclose(o1, o3)


@hypothesis.settings(**SETTINGS)
@hypothesis.given(
    blocks=st.integers(1, 4),
    block=st.sampled_from([16, 32]),
    d=st.sampled_from([32, 64, 128]),
)
def test_fused_ln_shape_sweep(blocks, block, d):
    x, res, w, b = _xw(blocks * block, d, seed=2)
    o1, r1 = layernorm.fused_dropout_residual_layernorm(
        x, res, w, b, p=0.0, block=block)
    o2, r2 = ref.fused_dropout_residual_layernorm(x, res, w, b, p=0.0)
    np.testing.assert_allclose(o1, o2, atol=1e-4, rtol=1e-3)


def test_ln_output_is_normalized():
    x, res, w, b = _xw(32, 128, seed=3)
    o, _ = layernorm.fused_dropout_residual_layernorm(
        x, res, jnp.ones(128), jnp.zeros(128), p=0.0)
    np.testing.assert_allclose(np.asarray(o).mean(-1), 0.0, atol=1e-4)
    np.testing.assert_allclose(np.asarray(o).std(-1), 1.0, atol=1e-2)


@pytest.mark.parametrize("d", [32, 64, 128])
def test_rope_matches_ref(d):
    x = jax.random.normal(jax.random.PRNGKey(4), (2, 4, 128, d), jnp.float32)
    np.testing.assert_allclose(
        rope.rope(x), ref.rope(x), atol=1e-4, rtol=1e-3)


def test_rope_preserves_norm():
    """Rotation preserves the norm of every (x1, x2) pair."""
    x = jax.random.normal(jax.random.PRNGKey(5), (1, 1, 64, 64), jnp.float32)
    y = np.asarray(rope.rope(x))
    xn = np.asarray(x)
    half = 32
    n_in = xn[..., :half] ** 2 + xn[..., half:] ** 2
    n_out = y[..., :half] ** 2 + y[..., half:] ** 2
    np.testing.assert_allclose(n_in, n_out, atol=1e-4)


def test_rope_position_zero_is_identity():
    x = jax.random.normal(jax.random.PRNGKey(6), (1, 1, 64, 32), jnp.float32)
    y = rope.rope(x)
    np.testing.assert_allclose(y[0, 0, 0], x[0, 0, 0], atol=1e-5)
