"""AOT pipeline tests: HLO-text lowering is well formed and the entry
list covers what the Rust runtime expects."""

import jax
import jax.numpy as jnp
import pytest

from compile import aot, model as m


def test_to_hlo_text_roundtrips_simple_fn():
    def fn(x, y):
        return (jnp.matmul(x, y) + 2.0,)

    spec = jax.ShapeDtypeStruct((2, 2), jnp.float32)
    lowered = jax.jit(fn).lower(spec, spec)
    text = aot.to_hlo_text(lowered)
    assert "HloModule" in text
    assert "ROOT" in text
    # 64-bit-id proto issue is avoided by going through text
    assert "custom-call" not in text


def test_entry_list_is_complete():
    cfg = m.tiny_config()
    names = [e[0] for e in aot.entries(cfg)]
    for required in [
        "gemm256", "attn_fwd_b1", "attn_fwd_b8", "fused_layernorm",
        "rope", "init_params", "train_step", "train_step_ref", "lm_loss",
    ]:
        assert required in names, names


def test_entry_metadata_has_shapes():
    cfg = m.tiny_config()
    for name, fn, specs, extra in aot.entries(cfg):
        outs = jax.eval_shape(fn, *specs)
        meta = aot._meta(specs, outs)
        assert meta["inputs"], name
        assert meta["outputs"], name
        for i in meta["inputs"]:
            assert all(d > 0 for d in i["shape"]) or i["shape"] == [], name


@pytest.mark.slow
def test_kernel_entry_lowers_without_custom_calls():
    cfg = m.tiny_config()
    for name, fn, specs, extra in aot.entries(cfg):
        if name != "gemm256":
            continue
        lowered = jax.jit(fn).lower(*specs)
        text = aot.to_hlo_text(lowered)
        assert "custom-call" not in text, name
