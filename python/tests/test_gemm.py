"""GEMM kernel vs oracle — shapes/dtypes swept with hypothesis."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import gemm, ref

SETTINGS = dict(deadline=None, max_examples=12,
                suppress_health_check=[hypothesis.HealthCheck.too_slow])


def _rand(key, shape, dtype):
    return jax.random.normal(jax.random.PRNGKey(key), shape, jnp.float32).astype(dtype)


@pytest.mark.parametrize("m,n,k", [(64, 64, 64), (128, 64, 192), (256, 128, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_matches_ref(m, n, k, dtype):
    a, b = _rand(0, (m, k), dtype), _rand(1, (k, n), dtype)
    got = gemm.matmul(a, b, block_m=64, block_n=64, block_k=64)
    want = ref.matmul(a, b)
    atol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), atol=atol, rtol=1e-2)


@hypothesis.settings(**SETTINGS)
@hypothesis.given(
    bm=st.sampled_from([16, 32, 64]),
    bn=st.sampled_from([16, 32, 64]),
    bk=st.sampled_from([16, 32, 64]),
    mm=st.integers(1, 3),
    nm=st.integers(1, 3),
    km=st.integers(1, 3),
)
def test_matmul_block_sweep(bm, bn, bk, mm, nm, km):
    """Any block decomposition must give the same answer (the paper's
    tile-size flexibility: multiple MFMA shapes per kernel)."""
    m, n, k = bm * mm, bn * nm, bk * km
    a, b = _rand(2, (m, k), jnp.float32), _rand(3, (k, n), jnp.float32)
    got = gemm.matmul(a, b, block_m=bm, block_n=bn, block_k=bk)
    np.testing.assert_allclose(got, ref.matmul(a, b), atol=1e-4, rtol=1e-3)


def test_bf16_accumulates_in_f32():
    # 1024-long dot of ones: exact in f32 accumulation, would round in
    # bf16 accumulation.
    a = jnp.ones((16, 1024), jnp.bfloat16)
    b = jnp.ones((1024, 16), jnp.bfloat16)
    got = gemm.matmul(a, b, block_m=16, block_n=16, block_k=128,
                      out_dtype=jnp.float32)
    np.testing.assert_allclose(got, 1024.0)


def test_out_dtype_override():
    a, b = _rand(4, (64, 64), jnp.float32), _rand(5, (64, 64), jnp.float32)
    got = gemm.matmul(a, b, block_m=64, block_n=64, block_k=64,
                      out_dtype=jnp.bfloat16)
    assert got.dtype == jnp.bfloat16


def test_rejects_ragged_shapes():
    a, b = _rand(6, (65, 64), jnp.float32), _rand(7, (64, 64), jnp.float32)
    with pytest.raises(AssertionError):
        gemm.matmul(a, b, block_m=64, block_n=64, block_k=64)


def test_pick_blocks_divides():
    for m, n, k in [(256, 512, 128), (96, 80, 48), (1024, 1024, 1024)]:
        bm, bn, bk = gemm.pick_blocks(m, n, k)
        assert m % bm == 0 and n % bn == 0 and k % bk == 0
        assert bm <= 128 and bn <= 128 and bk <= 128
