"""Attention kernels vs dense oracle: MHA/GQA x causal/non-causal,
forward and backward, plus hypothesis sweeps over shapes."""

import hypothesis
import hypothesis.strategies as st
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import attention, ref

SETTINGS = dict(deadline=None, max_examples=10,
                suppress_health_check=[hypothesis.HealthCheck.too_slow])


def _qkv(b, hq, hkv, n, d, dtype=jnp.float32, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (b, hq, n, d), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (b, hkv, n, d), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (b, hkv, n, d), jnp.float32).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("hq,hkv", [(4, 4), (8, 2)])  # MHA and GQA
@pytest.mark.parametrize("d", [32, 64])
def test_forward_matches_ref(causal, hq, hkv, d):
    q, k, v = _qkv(2, hq, hkv, 128, d)
    got = attention.attention(q, k, v, causal)
    want = ref.attention(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, atol=2e-3, rtol=1e-2)


@pytest.mark.parametrize("causal", [False, True])
@pytest.mark.parametrize("hq,hkv", [(4, 4), (4, 2)])
def test_backward_matches_ref(causal, hq, hkv):
    q, k, v = _qkv(1, hq, hkv, 128, 32, seed=3)

    def loss_k(q, k, v):
        return (attention.attention(q, k, v, causal) ** 2).sum()

    def loss_r(q, k, v):
        return (ref.attention(q, k, v, causal=causal) ** 2).sum()

    gk = jax.grad(loss_k, argnums=(0, 1, 2))(q, k, v)
    gr = jax.grad(loss_r, argnums=(0, 1, 2))(q, k, v)
    for name, x, y in zip("qkv", gk, gr):
        np.testing.assert_allclose(
            x, y, atol=5e-3, rtol=1e-2, err_msg=f"d{name}")


@hypothesis.settings(**SETTINGS)
@hypothesis.given(
    b=st.integers(1, 2),
    g=st.sampled_from([1, 2, 4]),
    hkv=st.sampled_from([1, 2]),
    nq_blocks=st.integers(1, 3),
    d=st.sampled_from([16, 32, 64]),
    causal=st.booleans(),
    block=st.sampled_from([32, 64]),
)
def test_forward_shape_sweep(b, g, hkv, nq_blocks, d, causal, block):
    n = block * nq_blocks
    q, k, v = _qkv(b, g * hkv, hkv, n, d, seed=7)
    got = attention.attention(q, k, v, causal, None, block, block)
    want = ref.attention(q, k, v, causal=causal)
    np.testing.assert_allclose(got, want, atol=3e-3, rtol=1e-2)


def test_bf16_inputs():
    q, k, v = _qkv(1, 4, 2, 128, 64, dtype=jnp.bfloat16, seed=9)
    got = attention.attention(q, k, v, True)
    want = ref.attention(q, k, v, causal=True)
    np.testing.assert_allclose(
        got.astype(jnp.float32), want.astype(jnp.float32), atol=3e-2, rtol=5e-2)
    assert got.dtype == jnp.bfloat16


def test_sm_scale_override():
    q, k, v = _qkv(1, 2, 2, 64, 32, seed=11)
    got = attention.attention(q, k, v, False, 0.5)
    want = ref.attention(q, k, v, causal=False, sm_scale=0.5)
    np.testing.assert_allclose(got, want, atol=2e-3, rtol=1e-2)


def test_causal_first_row_attends_only_self():
    q, k, v = _qkv(1, 1, 1, 64, 32, seed=13)
    got = attention.attention(q, k, v, True)
    # row 0 can only attend to position 0 -> output == v[0]
    np.testing.assert_allclose(got[0, 0, 0], v[0, 0, 0], atol=1e-5)


def test_gqa_equals_mha_with_repeated_kv():
    """GQA(hq=4, hkv=2) must equal MHA with KV explicitly repeated."""
    q, k, v = _qkv(1, 4, 2, 64, 32, seed=17)
    got = attention.attention(q, k, v, False)
    krep = jnp.repeat(k, 2, axis=1)
    vrep = jnp.repeat(v, 2, axis=1)
    want = attention.attention(q, krep, vrep, False)
    np.testing.assert_allclose(got, want, atol=1e-5)
