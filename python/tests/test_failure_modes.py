"""Failure injection / edge cases across the python layer."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile.kernels import attention, gemm, layernorm, ref


def test_gemm_rejects_mismatched_inner_dims():
    a = jnp.zeros((64, 64), jnp.float32)
    b = jnp.zeros((32, 64), jnp.float32)
    with pytest.raises(AssertionError):
        gemm.matmul(a, b, block_m=64, block_n=64, block_k=32)


def test_attention_rejects_non_divisible_gqa():
    q = jnp.zeros((1, 3, 64, 32), jnp.float32)
    k = jnp.zeros((1, 2, 64, 32), jnp.float32)
    with pytest.raises(AssertionError):
        attention.attention(q, k, k)


def test_layernorm_rejects_ragged_rows():
    x = jnp.zeros((33, 64), jnp.float32)
    w = jnp.ones(64)
    with pytest.raises(AssertionError):
        layernorm.fused_dropout_residual_layernorm(
            x, x, w, jnp.zeros(64), block=32)


def test_attention_handles_large_magnitude_logits():
    """Online softmax must not overflow where naive softmax would."""
    q = 30.0 * jax.random.normal(jax.random.PRNGKey(0), (1, 1, 64, 32))
    k = 30.0 * jax.random.normal(jax.random.PRNGKey(1), (1, 1, 64, 32))
    v = jax.random.normal(jax.random.PRNGKey(2), (1, 1, 64, 32))
    o = attention.attention(q, k, v, False, 1.0)
    assert np.isfinite(np.asarray(o)).all()
    want = ref.attention(q, k, v, causal=False, sm_scale=1.0)
    np.testing.assert_allclose(o, want, atol=5e-3, rtol=1e-2)


def test_attention_zero_values_give_zero_output():
    q = jax.random.normal(jax.random.PRNGKey(3), (1, 2, 64, 32))
    k = jax.random.normal(jax.random.PRNGKey(4), (1, 2, 64, 32))
    v = jnp.zeros((1, 2, 64, 32), jnp.float32)
    o = attention.attention(q, k, v, True)
    np.testing.assert_allclose(o, 0.0, atol=1e-6)


def test_dropout_p_one_is_degenerate_but_finite():
    x = jnp.ones((32, 32), jnp.float32)
    w = jnp.ones(32)
    o, r = layernorm.fused_dropout_residual_layernorm(
        x, x, w, jnp.zeros(32), p=0.99, seed=1)
    assert np.isfinite(np.asarray(o)).all()


def test_gemm_zero_matrix():
    a = jnp.zeros((64, 64), jnp.float32)
    b = jnp.zeros((64, 64), jnp.float32)
    out = gemm.matmul(a, b, block_m=64, block_n=64, block_k=64)
    np.testing.assert_array_equal(out, 0.0)
