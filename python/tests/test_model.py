"""L2 model tests: shapes, loss sanity, kernel/reference parity, and the
flat-parameter training API the Rust runtime drives."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model as m


@pytest.fixture(scope="module")
def cfg():
    return m.tiny_config()


@pytest.fixture(scope="module")
def params(cfg):
    return m.init_params(cfg, jax.random.PRNGKey(0))


def test_forward_shapes(cfg, params):
    tokens = jnp.zeros((2, cfg.seq_len), jnp.int32)
    logits = m.forward(cfg, params, tokens, use_kernels=False)
    assert logits.shape == (2, cfg.seq_len, cfg.vocab)


def test_initial_loss_near_uniform(cfg, params):
    batch = m.synthetic_batch(cfg, jax.random.PRNGKey(1), 2)
    loss = m.loss_fn(cfg, params, batch, use_kernels=False)
    assert abs(float(loss) - np.log(cfg.vocab)) < 0.5


def test_kernel_path_matches_reference_path(cfg, params):
    """The paper's stability claim at micro scale: Pallas-attention loss
    equals dense-attention loss."""
    batch = m.synthetic_batch(cfg, jax.random.PRNGKey(2), 2)
    lk = m.loss_fn(cfg, params, batch, use_kernels=True)
    lr = m.loss_fn(cfg, params, batch, use_kernels=False)
    np.testing.assert_allclose(float(lk), float(lr), atol=1e-4, rtol=1e-4)


def test_kernel_gradients_match_reference(cfg, params):
    batch = m.synthetic_batch(cfg, jax.random.PRNGKey(3), 1)
    gk = jax.grad(lambda p: m.loss_fn(cfg, p, batch, True))(params)
    gr = jax.grad(lambda p: m.loss_fn(cfg, p, batch, False))(params)
    fk, _ = jax.flatten_util.ravel_pytree(gk)
    fr, _ = jax.flatten_util.ravel_pytree(gr)
    np.testing.assert_allclose(fk, fr, atol=2e-4, rtol=1e-2)


def test_flat_roundtrip(cfg, params):
    n, unravel = m.flat_spec(cfg)
    flat, _ = jax.flatten_util.ravel_pytree(params)
    assert flat.shape == (n,)
    back = unravel(flat)
    fb, _ = jax.flatten_util.ravel_pytree(back)
    np.testing.assert_array_equal(flat, fb)


def test_train_step_decreases_loss(cfg):
    fns = m.make_flat_fns(cfg, lr=0.1)
    (flat,) = fns["init"](jnp.array([0], jnp.int32))
    mom = jnp.zeros_like(flat)
    batch = m.synthetic_batch(cfg, jax.random.PRNGKey(4), 4)
    step = jax.jit(fns["train_step"])
    losses = []
    for _ in range(8):
        flat, mom, loss = step(flat, mom, batch)
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.2, losses


def test_train_step_paths_agree_initially(cfg):
    """First-step loss must be identical across kernel and reference
    paths (same params, same batch)."""
    fns = m.make_flat_fns(cfg)
    (flat,) = fns["init"](jnp.array([7], jnp.int32))
    mom = jnp.zeros_like(flat)
    batch = m.synthetic_batch(cfg, jax.random.PRNGKey(5), 2)
    _, _, lk = fns["train_step"](flat, mom, batch)
    _, _, lr = fns["train_step_ref"](flat, mom, batch)
    np.testing.assert_allclose(float(lk), float(lr), atol=1e-4)


def test_lm_loss_entry(cfg):
    fns = m.make_flat_fns(cfg)
    (flat,) = fns["init"](jnp.array([0], jnp.int32))
    batch = m.synthetic_batch(cfg, jax.random.PRNGKey(6), 2)
    (loss,) = fns["lm_loss"](flat, batch)
    assert np.isfinite(float(loss))


def test_synthetic_batch_in_vocab(cfg):
    batch = m.synthetic_batch(cfg, jax.random.PRNGKey(8), 4)
    assert batch.shape == (4, cfg.seq_len + 1)
    assert batch.dtype == jnp.int32
    assert int(batch.min()) >= 0 and int(batch.max()) < cfg.vocab
